"""CLI: drive a data-parallel training job on the PROCESS world.

The smallest end-to-end demonstration of DESIGN.md §10: every rank is a
real OS process behind a socket proxy endpoint, checkpoints are written by
the children into a shared content-addressed store, and (optionally) a
rank is SIGKILLed mid-run so the fault-tolerant driver proves the
detect -> bump -> abort -> reshaped-restart loop on real PIDs.

    PYTHONPATH=src python -m repro.launch.procrun --ranks 4 --steps 20
    PYTHONPATH=src python -m repro.launch.procrun --ranks 4 --steps 20 \
        --kill-rank 2 --kill-step 8          # real SIGKILL, auto-recovery
"""
from __future__ import annotations

import argparse
import os
import signal
import tempfile
from pathlib import Path

from repro.core import MPIJob
from repro.distributed.faults import FaultTolerantDriver
from repro.distributed.proxy_grad import make_dp_app


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--ranks", type=int, default=4)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--ckpt-root", default=None,
                    help="checkpoint root (default: a fresh temp dir)")
    ap.add_argument("--kill-rank", type=int, default=None,
                    help="SIGKILL this rank's process at --kill-step")
    ap.add_argument("--kill-step", type=int, default=None)
    args = ap.parse_args(argv)

    root = Path(args.ckpt_root or tempfile.mkdtemp(prefix="procrun-"))
    init_fn, dp_step = make_dp_app()
    kill_rank, kill_step = args.kill_rank, args.kill_step

    def step_fn(mpi, st, k):
        if (kill_rank is not None and mpi.generation == 0
                and k == (kill_step if kill_step is not None else 0)
                and mpi.rank == kill_rank):
            print(f"[procrun] rank {mpi.rank} (pid {os.getpid()}) "
                  f"SIGKILLing itself at step {k}")
            os.kill(os.getpid(), signal.SIGKILL)
        return dp_step(mpi, st, k)

    driver = FaultTolerantDriver(
        job_factory=lambda ws, ms: MPIJob(
            ws or args.ranks, step_fn, init_fn, transport="proc",
            membership=ms),
        restart_factory=lambda d, tr, ws, dead, ms: MPIJob.restart(
            d, step_fn, init_fn, transport="proc", world_size=ws,
            dead_ranks=dead, membership=ms),
        ckpt_root=root, ckpt_every=args.ckpt_every)
    out = driver.run(args.steps, transport_after_failure="proc")
    print(f"[procrun] done: world={len(out)} "
          f"generation={driver.membership.generation} "
          f"loss={out[0].get('loss'):.6f} ckpts={root}")
    for ev in driver.events:
        print(f"[procrun]   {ev}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
