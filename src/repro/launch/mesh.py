"""Mesh construction.  Functions, not module-level constants — importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def _make(shape, axes):
    return compat_make_mesh(shape, axes)


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: ``axis_types`` (and
    ``jax.sharding.AxisType``) only exist in newer releases; older ones
    default every axis to Auto anyway, which is what we want."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model); "pod" crosses DCN."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_local_mesh(n: int | None = None, model: int = 1):
    """Mesh over locally visible devices (smoke tests, CPU examples)."""
    n = n or len(jax.devices())
    assert n % model == 0, (n, model)
    return _make((n // model, model), ("data", "model"))
