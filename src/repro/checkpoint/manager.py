"""Fleet-level checkpoint manager: the paper's protocol at the training
loop (DESIGN.md §2 mapping, §9 storage layout).

  drain    = jax.block_until_ready on the state (all dispatched steps and
             async transfers complete) + wait for the previous async write
  snapshot = device->host copy of the pure pytree (replicated shards
             deduped BEFORE the copy), handed to a background writer
             (the storage 'proxy'; training never blocks on the filesystem)
  commit   = content-addressed chunks + v3 manifest, atomic rename;
             unchanged chunks are REFERENCED, not rewritten (incremental)
  restore  = newest VALID checkpoint (corrupt/partial ones skipped,
             manifest-only fast validation), resharded onto the current
             mesh

Layout: <root>/chunks/<digest>.<ext>  — shared, content-addressed
        <root>/step_<N>/MANIFEST.json — references chunks by name

GC is refcounting over live manifests: step dirs beyond `keep` (and
corrupt ones) are removed first, then every chunk no remaining manifest
references; the last remaining valid checkpoint is never removed.
"""
from __future__ import annotations

import re
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import List, Optional

import jax

from repro.checkpoint import chunkstore
from repro.checkpoint import serialization as ser
from repro.checkpoint.resharding import restore_resharded
from repro.core import metrics as _metrics
from repro.core import trace as _trace

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3,
                 async_write: bool = True, generation: int = 0,
                 writer_threads: Optional[int] = None,
                 store=None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        #: membership generation (elastic restart epoch) stamped into every
        #: manifest; the fault-tolerant driver bumps it on reshape
        self.generation = generation
        #: content-addressed store shared by every step this manager
        #: writes: a backend instance, a ``StoreSpec``, any spec string
        #: ``StoreSpec.parse`` accepts (``remote://`` single or sharded),
        #: or a path (default: a local directory under the manager root).
        #: With a caching backend, saves upload only chunks the server
        #: lacks and restores fetch only chunks the cache lacks
        #: (DESIGN.md §11, §15).
        self.store = chunkstore.open_store(store,
                                           default=self.root / "chunks")
        #: compress/write pool width (<=1 disables the parallel pipeline)
        self.writer_threads = (ser.DEFAULT_WORKERS if writer_threads is None
                               else writer_threads)
        self._pending: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        #: dirs already validated: checkpoints are immutable once the
        #: manifest commits (and gc protects every retained manifest's
        #: chunks), so _gc never re-validates a known-valid dir
        self._known_valid: set = set()
        #: metrics registry group (DESIGN.md §16): same mapping API the
        #: ad-hoc dict had — tests index it, serialization.py read-modify-
        #: writes stage timings into it — but every mutation is atomic
        #: under the group lock and ``snapshot()`` is one consistent view
        self.stats = _metrics.MetricGroup(
            "ckpt_manager",
            {"saves": 0, "drain_s": 0.0, "snapshot_s": 0.0,
             "write_s": 0.0, "gc_removed": 0,
             # pipeline stage timings (summed across pool threads)
             "hash_s": 0.0, "compress_s": 0.0, "io_s": 0.0,
             # incremental accounting, cumulative and per-save
             "bytes_written": 0, "bytes_referenced": 0,
             "last_bytes_written": 0, "last_bytes_referenced": 0,
             "chunks_gc_removed": 0,
             # cross-host transfer accounting (networked stores;
             # zero for local): wire bytes actually shipped vs
             # wire bytes the server already held
             "last_bytes_uploaded": 0,
             "last_bytes_referenced_remote": 0,
             # restore pipeline stage timings
             "restores": 0, "restore_io_s": 0.0,
             "restore_decompress_s": 0.0, "restore_device_s": 0.0})

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, meta: Optional[dict] = None) -> Path:
        """Drain -> host snapshot -> async commit.  Returns the ckpt dir.
        The manifest meta records the SOURCE world (device count + mesh
        when the caller provides one) and the membership generation, so a
        later elastic restore can report the topology change."""
        save_span = _trace.begin("ckptmgr.save", cat="ckpt",
                                 generation=self.generation,
                                 args={"step": step})
        t0 = time.time()
        with _trace.span("ckptmgr.drain", parent=save_span, cat="ckpt"):
            jax.block_until_ready(state)      # drain dispatched computation
            self.wait()                       # drain the previous async write
        self.stats["drain_s"] += time.time() - t0

        t0 = time.time()
        with _trace.span("ckptmgr.snapshot", parent=save_span, cat="ckpt"):
            host_state = ser.snapshot_to_host(state)  # sync: donation-safe
        self.stats["snapshot_s"] += time.time() - t0

        ckpt_dir = self.root / f"step_{step:010d}"
        meta = dict(meta or {}, step=step, time=time.time())
        meta.setdefault("world", {"n_devices": len(jax.devices())})
        meta.setdefault("generation", self.generation)

        def _write():
            t1 = time.time()
            w0 = self.store.stats["bytes_written"]
            r0 = self.store.stats["bytes_referenced"]
            u0 = self.store.stats.get("bytes_uploaded", 0)
            rr0 = self.store.stats.get("bytes_referenced_remote", 0)
            try:
                # context-manager span: runs on the ckpt-writer thread, so
                # the explicit parent handle (not the spawning thread's
                # stack) links it under the save — and chunk-store RPC
                # spans inside save_shards nest under it in turn
                with _trace.span("ckptmgr.write", parent=save_span,
                                 cat="ckpt", args={"step": step}):
                    ser.save_shards(ckpt_dir, host_state, meta=meta,
                                    store=self.store,
                                    workers=self.writer_threads,
                                    stats=self.stats)
            except BaseException as e:  # surfaced on next wait()
                # NO gc: it would run against a partial dir, and must not
                # get a chance to touch the previous valid checkpoint
                self._last_error = e
                self.stats["write_s"] += time.time() - t1
                save_span.end(outcome="failed", error=type(e).__name__)
                return
            self.stats["write_s"] += time.time() - t1
            # last_* deltas describe the last COMPLETED save only — a
            # failed partial write must not overwrite them
            self.stats["last_bytes_written"] = \
                self.store.stats["bytes_written"] - w0
            self.stats["last_bytes_referenced"] = \
                self.store.stats["bytes_referenced"] - r0
            self.stats["bytes_written"] = self.store.stats["bytes_written"]
            self.stats["bytes_referenced"] = \
                self.store.stats["bytes_referenced"]
            self.stats["last_bytes_uploaded"] = \
                self.store.stats.get("bytes_uploaded", 0) - u0
            self.stats["last_bytes_referenced_remote"] = \
                self.store.stats.get("bytes_referenced_remote", 0) - rr0
            try:
                self._gc()
            except BaseException as e:
                self._last_error = e
            save_span.end(
                outcome="ok",
                bytes_written=self.stats["last_bytes_written"],
                bytes_referenced=self.stats["last_bytes_referenced"])

        self.stats["saves"] += 1
        if self.async_write:
            self._pending = threading.Thread(target=_write, daemon=True,
                                             name="ckpt-writer")
            self._pending.start()
        else:
            _write()
            self._raise_pending()
        return ckpt_dir

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise RuntimeError("async checkpoint write failed") from err

    def delta_write_fraction(self) -> float:
        """Bytes written / bytes handled for the LAST completed save — the
        observable incremental ratio (1.0 = full rewrite, ~0.0 = everything
        referenced)."""
        total = (self.stats["last_bytes_written"]
                 + self.stats["last_bytes_referenced"])
        return self.stats["last_bytes_written"] / total if total else 1.0

    def remote_transfer_fraction(self) -> float:
        """Wire bytes uploaded / wire bytes handled for the LAST completed
        save against a networked store (1.0 = the server had nothing,
        ~0.0 = everything was already there).  1.0 for local stores, which
        never transfer."""
        total = (self.stats["last_bytes_uploaded"]
                 + self.stats["last_bytes_referenced_remote"])
        return self.stats["last_bytes_uploaded"] / total if total else 1.0

    def store_health(self) -> Optional[list]:
        """Per-shard health when the store is a sharded tier (endpoint,
        up/down, cooldown, wire counters — DESIGN.md §15); None for
        local and single-server stores."""
        fn = getattr(self.store, "health", None)
        return fn() if fn is not None else None

    # ---------------------------------------------------------------- restore
    def list_steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir() if self.root.exists() else []:
            m = _STEP_RE.match(p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_valid(self) -> Optional[Path]:
        """Newest restorable checkpoint.  v3 validation is manifest-only
        (parse + stat every referenced chunk) — no blob reads, so scanning
        a long history costs milliseconds, not a full re-read."""
        for step in reversed(self.list_steps()):
            d = self.root / f"step_{step:010d}"
            if ser.validate(d, store=self.store):
                return d
        return None

    def restore(self, template, shardings=None,
                ckpt_dir: Optional[Path] = None, mesh=None, rules=None):
        """Restore newest valid checkpoint (resharded).  Layouts come from
        `shardings`, or are derived for `mesh` (+ optional `rules`) when
        given — the elastic cross-topology path.  Returns (state, meta) or
        (None, None) if nothing valid exists.

        Because fast validation is manifest-only, a size-preserving bit
        flip is first caught by the digest check DURING the restore read;
        when auto-picking, such a dir is skipped and the next older valid
        checkpoint is served (the pre-chunk-store 'corrupt ones skipped'
        guarantee).  An explicit `ckpt_dir` still raises."""
        if ckpt_dir is not None:
            with _trace.span("ckptmgr.restore", cat="ckpt",
                             args={"dir": ckpt_dir.name}):
                state = restore_resharded(ckpt_dir, template, shardings,
                                          mesh=mesh, rules=rules,
                                          store=self.store,
                                          workers=self.writer_threads,
                                          stats=self.stats)
            self.stats["restores"] += 1
            return state, ser.load_manifest(ckpt_dir).get("meta", {})
        for step in reversed(self.list_steps()):
            d = self.root / f"step_{step:010d}"
            if not ser.validate(d, store=self.store):
                continue
            try:
                with _trace.span("ckptmgr.restore", cat="ckpt",
                                 args={"dir": d.name}):
                    state = restore_resharded(d, template, shardings,
                                              mesh=mesh, rules=rules,
                                              store=self.store,
                                              workers=self.writer_threads,
                                              stats=self.stats)
            except (OSError, zlib.error, RuntimeError, ValueError):
                # payload-level corruption the fast validate can't see
                # (digest mismatch, truncated codec stream): skip this dir
                self._known_valid.discard(d.name)
                continue
            self.stats["restores"] += 1
            return state, ser.load_manifest(d).get("meta", {})
        return None, None

    # --------------------------------------------------------------------- gc
    def _gc(self) -> None:
        """Two-phase refcounting gc.

        Phase 1 (step dirs): corrupt/partial dirs are ALWAYS removed (they
        can never be restored and used to accumulate forever); of the valid
        ones, the newest `keep` are retained — and the last remaining valid
        checkpoint is never removed, whatever `keep` says.

        Phase 2 (chunks): the union of chunk names referenced by every
        RETAINED manifest is the live set; everything else in the store is
        unlinked.  A chunk shared by a removed and a retained step survives
        (that is the point of content addressing)."""
        dirs = [self.root / f"step_{s:010d}" for s in self.list_steps()]
        try:
            valid = [d for d in dirs
                     if d.name in self._known_valid
                     or ser.validate(d, store=self.store,
                                     raise_unreachable=True)]
        except ConnectionError:
            # the chunk service can't be asked: every un-cached dir would
            # read "invalid" and be DELETED on a transient outage — skip
            # gc entirely this round (conservative, like an unreadable
            # manifest below)
            return
        self._known_valid = {d.name for d in valid}
        invalid = [d for d in dirs if d not in valid]
        excess = valid[:-self.keep] if self.keep else []
        for d in invalid + excess:
            shutil.rmtree(d, ignore_errors=True)
            self._known_valid.discard(d.name)
            self.stats["gc_removed"] += 1
        live: set = set()
        for d in valid:
            if d in excess:
                continue
            try:
                live.update(ser.manifest_chunks(ser.load_manifest(d)))
            except (OSError, ValueError, KeyError):
                # unreadable manifest in a dir we chose to keep: be
                # conservative and skip chunk gc entirely this round
                return
        try:
            self.stats["chunks_gc_removed"] += self.store.gc(live)
        except ConnectionError:
            pass    # service outage mid-gc: chunks persist, retry next round
