"""Fleet-level checkpoint manager: the paper's protocol at the training
loop (DESIGN.md §2 mapping).

  drain    = jax.block_until_ready on the state (all dispatched steps and
             async transfers complete) + wait for the previous async write
  snapshot = device->host copy of the pure pytree, handed to a background
             writer thread (the storage 'proxy'; training never blocks on
             the filesystem)
  commit   = per-shard files + manifest, atomic rename, crc32
  restore  = newest VALID checkpoint (corrupt/partial ones skipped),
             resharded onto the current mesh

Layout: <root>/step_<N>/{leaf shards, MANIFEST.json}
"""
from __future__ import annotations

import json
import re
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import serialization as ser
from repro.checkpoint.resharding import restore_resharded

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointManager:
    def __init__(self, root: str | Path, keep: int = 3,
                 async_write: bool = True, generation: int = 0):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        #: membership generation (elastic restart epoch) stamped into every
        #: manifest; the fault-tolerant driver bumps it on reshape
        self.generation = generation
        self._pending: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None
        #: dirs already crc-validated: checkpoints are immutable once the
        #: manifest commits, so _gc never re-reads a known-valid dir
        self._known_valid: set = set()
        self.stats = {"saves": 0, "drain_s": 0.0, "snapshot_s": 0.0,
                      "write_s": 0.0, "gc_removed": 0}

    # ------------------------------------------------------------------ save
    def save(self, step: int, state, meta: Optional[dict] = None) -> Path:
        """Drain -> host snapshot -> async commit.  Returns the ckpt dir.
        The manifest meta records the SOURCE world (device count + mesh
        when the caller provides one) and the membership generation, so a
        later elastic restore can report the topology change."""
        t0 = time.time()
        jax.block_until_ready(state)          # drain dispatched computation
        self.wait()                           # drain the previous async write
        self.stats["drain_s"] += time.time() - t0

        t0 = time.time()
        host_state = ser.snapshot_to_host(state)   # sync copy: donation-safe
        self.stats["snapshot_s"] += time.time() - t0

        ckpt_dir = self.root / f"step_{step:010d}"
        meta = dict(meta or {}, step=step, time=time.time())
        meta.setdefault("world", {"n_devices": len(jax.devices())})
        meta.setdefault("generation", self.generation)

        def _write():
            t1 = time.time()
            try:
                ser.save_shards(ckpt_dir, host_state, meta=meta)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e
            finally:
                self.stats["write_s"] += time.time() - t1

        self.stats["saves"] += 1
        if self.async_write:
            self._pending = threading.Thread(target=_write, daemon=True,
                                             name="ckpt-writer")
            self._pending.start()
        else:
            _write()
            self._raise_pending()
        return ckpt_dir

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None
        self._raise_pending()

    def _raise_pending(self) -> None:
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise RuntimeError("async checkpoint write failed") from err

    # ---------------------------------------------------------------- restore
    def list_steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir() if self.root.exists() else []:
            m = _STEP_RE.match(p.name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_valid(self) -> Optional[Path]:
        for step in reversed(self.list_steps()):
            d = self.root / f"step_{step:010d}"
            if ser.validate(d):
                return d
        return None

    def restore(self, template, shardings=None,
                ckpt_dir: Optional[Path] = None, mesh=None, rules=None):
        """Restore newest valid checkpoint (resharded).  Layouts come from
        `shardings`, or are derived for `mesh` (+ optional `rules`) when
        given — the elastic cross-topology path.  Returns (state, meta) or
        (None, None) if nothing valid exists."""
        d = ckpt_dir or self.latest_valid()
        if d is None:
            return None, None
        state = restore_resharded(d, template, shardings, mesh=mesh,
                                  rules=rules)
        meta = ser.load_manifest(d).get("meta", {})
        return state, meta

    # --------------------------------------------------------------------- gc
    def _gc(self) -> None:
        """Corrupt/partial dirs are ALWAYS removed (they can never be
        restored and used to accumulate forever); of the valid ones, the
        newest `keep` are retained — and the last remaining valid
        checkpoint is never removed, whatever `keep` says."""
        dirs = [self.root / f"step_{s:010d}" for s in self.list_steps()]
        valid = [d for d in dirs
                 if d.name in self._known_valid or ser.validate(d)]
        self._known_valid = {d.name for d in valid}
        invalid = [d for d in dirs if d not in valid]
        excess = valid[:-self.keep] if self.keep else []
        for d in invalid + excess:
            shutil.rmtree(d, ignore_errors=True)
            self._known_valid.discard(d.name)
            self.stats["gc_removed"] += 1
