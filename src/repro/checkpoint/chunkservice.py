"""Cross-host chunk service — the networked half of the pluggable
checkpoint store (DESIGN.md §11).

The paper's proxy argument applied to STORAGE: checkpoint against a
stable interface (``ChunkStoreBackend``), not an implementation (a host's
filesystem).  PR 4 made every rank a process behind a socket; the chunk
directory was the last host-local assumption.  This module removes it:

  * ``ChunkServer`` — serves a backing ``ChunkStore`` over sockets,
    reusing the process-world framing (``transport.write_frame_parts``
    / ``read_frame_mv``: 8-byte length + scatter-gather pickle body)
    and the same versioned command-batch shape the proxy wire protocol
    uses.  Chunk blobs at or above ``_OOB_MIN`` travel as pickle
    protocol-5 out-of-band buffers: a PUT gathers header + blob straight
    from the caller's buffer into ``sendmsg`` and a GET reply is decoded
    as a view over the one receive buffer — no intermediate ``bytes``
    concatenation on either side, in either direction.  Commands:
    HAS-many, PUT, GET(-many), REF, GC-live-set, SIZE, LIST, STATS.
    A request frame is read IN FULL before anything is applied, and the
    backing store commits with tmp-file + atomic rename — so a client
    SIGKILLed mid-upload (a torn frame, read as EOF) can never leave a
    partial chunk visible to ``has()``.
  * ``RemoteChunkStore`` — the client backend.  Connects lazily and
    re-connects after a fork (rank children each get their own socket),
    one request/reply cycle per call under a lock.
  * ``CachingChunkStore`` — a local ``ChunkStore`` cache layered over a
    remote.  Saves upload only chunks the SERVER doesn't have (batched
    HAS before upload); restores fetch only chunks the CACHE doesn't
    have and pin them locally — a restart on a fresh host (empty cache
    dir) transfers exactly the missing bytes.

Coherence story: chunks are immutable and content-addressed, so cache
and server can never disagree about a name's bytes — the only states are
"absent" and "identical".  The asymmetric views follow from that:
``has``/``has_many`` answer for the SERVER (the upload decision must be
authoritative for other hosts' restores), ``get``/``sizes`` answer
cache-first (reads want the nearest copy).  ``gc`` collects the CACHE
only — a server may back several writers whose live sets the client
can't see — but it also REGISTERS the caller's live set as a TTL
**lease** on the server, which makes server-side reclamation safe
without coordination: the explicit ``gc_remote`` and the server's own
optional auto-sweep both refuse to collect any chunk covered by an
unexpired lease, and the sweep additionally spares chunks younger than
a grace window (covering the upload→lease gap — a migration round
streamed but not yet committed can never be collected mid-flight).

Namespaces: a server partitions its root per namespace (one flat chunk
dir each), so independent jobs sharing one server cannot observe each
other through dedup or collect each other's chunks.

Spec grammar (``chunkstore.open_store``):

    remote://HOST:PORT[/NAMESPACE][?cache=DIR]
"""
from __future__ import annotations

import os
import pickle
import random
import re
import socket
import struct
import threading
import time
import urllib.parse
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.checkpoint.chunkstore import ChunkStore, ChunkStoreBackend
from repro.core import tunables
from repro.core.transport import (dumps_parts, loads_body, read_frame_mv,
                                  write_frame_parts)

#: versioned command batches, like the proxy wire protocol: a request is
#: ``(CHUNK_PROTOCOL_VERSION, namespace, [(cmd, args), ...])`` and the
#: reply is ``(True, [result, ...])`` or ``(False, exception)``.  Still
#: v1: the SG body encoding is self-describing (``loads_body`` accepts
#: both plain-pickle and SG bodies), so the frame change needs no bump.
CHUNK_PROTOCOL_VERSION = 1

#: blobs at least this large ride out-of-band (``pickle.PickleBuffer``)
#: in both directions; below it the plain in-band pickle is cheaper than
#: an extra iovec entry
_OOB_MIN = 1 << 16


def _oob(blob) -> Any:
    """Large blobs as zero-copy out-of-band buffers, small ones as bytes.
    The receiving side sees a memoryview over its single receive buffer
    for the former — ``_as_bytes`` converts at the API boundary."""
    if len(blob) >= _OOB_MIN:
        return pickle.PickleBuffer(blob)
    return bytes(blob)


def _as_bytes(blob) -> bytes:
    return blob if isinstance(blob, bytes) else bytes(blob)

#: chunk names and namespaces are digest-shaped tokens; anything else is
#: rejected server-side (a name is used as a path component)
_SAFE_TOKEN = re.compile(r"^[A-Za-z0-9._-]+$")


class ChunkServiceError(ConnectionError):
    """Chunk-service wire failure (torn reply, refused connection,
    protocol mismatch).  A ConnectionError subclass so every existing
    ``except OSError`` around restore/validate treats an unreachable
    server exactly like a missing local file."""


def _check_token(tok: str, what: str) -> str:
    # fullmatch (a trailing newline must not slip past a $-anchor) and no
    # dot-only tokens: namespace "." would alias the server's default
    # namespace and break cross-job isolation
    if (not _SAFE_TOKEN.fullmatch(tok) or ".." in tok
            or set(tok) == {"."}):
        raise ValueError(f"illegal {what} {tok!r}")
    return tok


def parse_spec(spec: str) -> Tuple[str, int, str, Optional[str]]:
    """``remote://host:port[/ns][?cache=DIR]`` -> (host, port, ns, cache).
    The cache value is percent-decoded (make_spec quotes it — cache dirs
    are user paths and may legally contain ``?``/``&``)."""
    if not spec.startswith("remote://"):
        raise ValueError(f"not a remote chunk-store spec: {spec!r}")
    rest = spec[len("remote://"):]
    cache: Optional[str] = None
    if "?" in rest:
        rest, query = rest.split("?", 1)
        for kv in query.split("&"):
            k, _, v = kv.partition("=")
            if k == "cache" and v:
                cache = urllib.parse.unquote(v)
            else:
                raise ValueError(f"unknown spec parameter {kv!r} in {spec!r}")
    ns = ""
    if "/" in rest:
        rest, ns = rest.split("/", 1)
        if ns:
            _check_token(ns, "namespace")
    host, _, port = rest.rpartition(":")
    if not host or not port.isdigit():
        raise ValueError(f"spec needs host:port, got {spec!r}")
    return host, int(port), ns, cache


def make_spec(host: str, port: int, namespace: str = "",
              cache: Optional[str | Path] = None) -> str:
    spec = f"remote://{host}:{port}"
    if namespace:
        spec += f"/{namespace}"
    if cache:
        spec += f"?cache={urllib.parse.quote(str(cache), safe='/')}"
    return spec


def store_from_spec(spec: str) -> ChunkStoreBackend:
    host, port, ns, cache = parse_spec(spec)
    remote = RemoteChunkStore(host, port, namespace=ns)
    if cache is None:
        return remote
    return CachingChunkStore(cache, remote)


# =========================================================================
# server
# =========================================================================

class ChunkServer:
    """Serve a directory of content-addressed chunks over sockets.

    One accept thread + one thread per connection (rank children, writer
    pools and restore pools each hold their own connection).  The backing
    ``ChunkStore`` is thread-safe and its writes are atomic renames, so
    concurrent PUTs of the same digest collapse to one file — the same
    idempotence the local store gives racing processes.

    GC LEASES: clients register their live chunk sets under named TTL
    leases (``lease``/``unlease`` commands; renewed automatically by every
    client-side gc round).  The GC-live-set command then treats the union
    of unexpired leases as live IN ADDITION to the caller's set, so one
    writer's reclamation can never collect another's chunks — and a
    migration pins each streamed-but-uncommitted round under its own
    lease.  With ``auto_gc_interval`` set, the server also sweeps on its
    own: a chunk is collected only when NO unexpired lease covers it AND
    it is older than ``gc_grace`` seconds (the grace spares the
    upload→lease gap of an in-flight save).
    """

    def __init__(self, root: str | Path, host: str = "127.0.0.1",
                 port: int = 0, advertise_host: Optional[str] = None,
                 auto_gc_interval: Optional[float] = None,
                 gc_grace: float = 60.0):
        self.root = Path(root)
        self.auto_gc_interval = auto_gc_interval
        self.gc_grace = gc_grace
        self._stores: Dict[str, ChunkStore] = {}
        #: {namespace: {lease_id: (monotonic expiry, frozenset(names))}}
        self._leases: Dict[str, Dict[str, Tuple[float, frozenset]]] = {}
        self._lock = threading.Lock()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(64)
        self._srv.settimeout(0.2)
        bound_host, self.port = self._srv.getsockname()[:2]
        # specs must carry an address CLIENTS can dial: a wildcard bind
        # ("0.0.0.0"/"::") is not one — cross-host deployments pass the
        # reachable name via advertise_host
        self.host = advertise_host or bound_host
        if self.host in ("0.0.0.0", "::"):
            self.host = socket.gethostname()
        self._halt = threading.Event()
        self._threads: List[threading.Thread] = []
        self._conns: List[socket.socket] = []
        self._accept: Optional[threading.Thread] = None
        self._sweeper: Optional[threading.Thread] = None

    @property
    def spec(self) -> str:
        return make_spec(self.host, self.port)

    def spec_for(self, namespace: str = "",
                 cache: Optional[str | Path] = None) -> str:
        return make_spec(self.host, self.port, namespace, cache)

    def backing(self, namespace: str = "") -> ChunkStore:
        """The per-namespace backing store (the server's own view — tests
        and ops poke it directly)."""
        if namespace:
            _check_token(namespace, "namespace")
        with self._lock:
            st = self._stores.get(namespace)
            if st is None:
                st = ChunkStore(self.root / namespace if namespace
                                else self.root)
                self._stores[namespace] = st
        return st

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ChunkServer":
        self._accept = threading.Thread(target=self._accept_loop,
                                        daemon=True, name="chunk-server")
        self._accept.start()
        if self.auto_gc_interval:
            self._sweeper = threading.Thread(target=self._sweep_loop,
                                             daemon=True,
                                             name="chunk-server-gc")
            self._sweeper.start()
        return self

    def stop(self, join_timeout: float = 5.0) -> None:
        self._halt.set()
        try:
            self._srv.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        if self._accept is not None:
            self._accept.join(join_timeout)
        if self._sweeper is not None:
            self._sweeper.join(join_timeout)
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            if t is not threading.current_thread():
                t.join(join_timeout)

    def __enter__(self) -> "ChunkServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- serving
    def _accept_loop(self) -> None:
        while not self._halt.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:          # server socket closed by stop()
                return
            t = threading.Thread(target=self._serve, args=(conn,),
                                 daemon=True, name="chunk-server-conn")
            with self._lock:
                self._conns.append(conn)
                self._threads.append(t)
            t.start()

    def _serve(self, conn: socket.socket) -> None:
        """One connection: read a WHOLE request frame, apply, reply.  A
        torn frame (client died mid-send) reads as EOF — the half-shipped
        PUT is dropped on the floor, never applied."""
        try:
            while not self._halt.is_set():
                blob = read_frame_mv(conn)
                if blob is None:
                    return
                try:
                    version, ns, cmds = loads_body(blob)
                    if version != CHUNK_PROTOCOL_VERSION:
                        raise ChunkServiceError(
                            f"client speaks chunk protocol v{version}, "
                            f"server v{CHUNK_PROTOCOL_VERSION}")
                    store = self.backing(ns)
                    results = [self._execute(ns, store, cmd, args)
                               for cmd, args in cmds]
                    reply = (True, results)
                except Exception as e:      # noqa: BLE001 - shipped back
                    reply = (False, e)
                write_frame_parts(conn, dumps_parts(reply))
        except (OSError, pickle.PickleError):
            return
        finally:
            try:
                conn.close()
            except OSError:
                pass
            # prune: a long-lived server sheds each disconnected client
            # (one socket per rank child / pool — they come and go)
            me = threading.current_thread()
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)
                if me in self._threads:
                    self._threads.remove(me)

    # --------------------------------------------------------------- leases
    def _lease_union(self, namespace: str) -> Set[str]:
        """Union of chunk names covered by unexpired leases in the
        namespace; expired leases are pruned as a side effect."""
        now = time.monotonic()
        out: Set[str] = set()
        with self._lock:
            table = self._leases.get(namespace)
            if not table:
                return out
            for lid in [k for k, (exp, _) in table.items() if exp < now]:
                del table[lid]
            for _, names in table.values():
                out.update(names)
        return out

    def sweep(self, grace: Optional[float] = None) -> int:
        """Server-initiated reclamation across every namespace touched so
        far: remove chunks covered by NO unexpired lease and older than
        ``grace`` seconds (file mtime).  The grace window protects chunks
        a client has uploaded but not yet covered with a lease or a
        committed manifest — mid-save and mid-migration-round state.
        Runs periodically when ``auto_gc_interval`` is set; callable
        directly for deterministic tests/ops."""
        grace = self.gc_grace if grace is None else grace
        cutoff = time.time() - grace
        removed_total = 0
        with self._lock:
            spaces = list(self._stores.items())
        for ns, store in spaces:
            protected = self._lease_union(ns)
            removed = 0
            for name in store.list_chunks():
                if name in protected:
                    continue
                p = store.root / name
                try:
                    if p.stat().st_mtime > cutoff:
                        continue
                    p.unlink()
                    removed += 1
                except OSError:
                    continue
            if removed:
                with store._lock:
                    store.stats["chunks_removed"] += removed
            removed_total += removed
        return removed_total

    def _sweep_loop(self) -> None:
        while not self._halt.wait(self.auto_gc_interval):
            try:
                self.sweep()
            except Exception:       # noqa: BLE001 - sweep must never die
                pass

    def _execute(self, ns: str, store: ChunkStore, cmd: str,
                 args: tuple) -> Any:
        if cmd == "has_many":
            (names,) = args
            out: Dict[str, int] = {}
            for n in names:
                _check_token(n, "chunk name")
                if store.has(n):
                    out[n] = store.size(n)
            return out
        if cmd == "put":
            name, blob, raw = args
            _check_token(name, "chunk name")
            # blob may be a memoryview over the request's receive buffer
            # (out-of-band PUT); the store writes any buffer object
            return store.put(name, blob, raw_bytes=raw)
        if cmd == "get":
            (name,) = args
            _check_token(name, "chunk name")
            return _oob(store.get(name))
        if cmd == "get_many":
            (names,) = args
            out = {}
            for n in names:
                _check_token(n, "chunk name")
                if store.has(n):
                    out[n] = _oob(store.get(n))
            return out
        if cmd == "ref":
            name, raw = args
            store.ref(name, raw)
            return None
        if cmd == "gc":
            # the caller's live set PLUS every unexpired lease: explicit
            # reclamation by one writer can never collect chunks another
            # client has registered as live
            (live,) = args
            return store.gc(set(live) | self._lease_union(ns))
        if cmd == "lease":
            lease_id, names, ttl = args
            _check_token(lease_id, "lease id")
            names = frozenset(names)
            for n in names:
                _check_token(n, "chunk name")
            with self._lock:
                self._leases.setdefault(ns, {})[lease_id] = (
                    time.monotonic() + float(ttl), names)
            return len(names)
        if cmd == "unlease":
            (lease_id,) = args
            with self._lock:
                table = self._leases.get(ns, {})
                return table.pop(lease_id, None) is not None
        if cmd == "leases":
            now = time.monotonic()
            with self._lock:
                table = dict(self._leases.get(ns, {}))
            return {lid: {"ttl": exp - now, "chunks": len(names)}
                    for lid, (exp, names) in table.items() if exp >= now}
        if cmd == "size":
            (name,) = args
            _check_token(name, "chunk name")
            return store.size(name)
        if cmd == "list":
            return sorted(store.list_chunks())
        if cmd == "stats":
            return dict(store.stats)
        raise ValueError(f"unknown chunk-service command {cmd!r}")


# =========================================================================
# client backends
# =========================================================================

class RemoteChunkStore(ChunkStoreBackend):
    """Socket client to a ``ChunkServer`` — a pure remote backend.

    Fork-safe by construction: the connection is opened lazily and keyed
    to the owning pid, so a forked rank child that inherited this object
    transparently opens its OWN socket instead of interleaving frames on
    the parent's.  One request/reply cycle at a time under a lock (the
    writer pool serializes here; the server side fans out per
    connection, so parallel clients scale, parallel calls on ONE client
    pipeline through one socket).

    Connection-layer failures (dial refused, torn write/read, EOF
    mid-reply) are retried up to ``REPRO_CHUNK_RETRIES`` attempts with
    doubling, jittered backoff from ``REPRO_CHUNK_RETRY_BASE_S`` — a
    chunk server bounced under the client (crash + restart, rolling
    upgrade) costs a short stall instead of a failed checkpoint.  Whole
    requests are replayed: every command is idempotent (content-addressed
    PUT, read-only GET/HAS, set-valued REF/LEASE), so a reply lost on the
    wire re-executes safely.  Errors the SERVER raised are never retried
    — those arrive on a healthy round trip and retrying cannot change
    them."""

    wants_batched_has = True
    root = None

    #: default TTL for the client's automatic live-set lease — long
    #: enough to bridge several save/gc rounds, short enough that a dead
    #: client's pin drains away on its own
    DEFAULT_LEASE_TTL = 600.0

    def __init__(self, host: str, port: int, namespace: str = "",
                 connect_timeout: float = 10.0):
        self.host, self.port = host, int(port)
        self.namespace = namespace
        if namespace:
            _check_token(namespace, "namespace")
        self.connect_timeout = connect_timeout
        self._sock: Optional[socket.socket] = None
        self._pid: Optional[int] = None
        self._lease_pid: Optional[int] = None
        self._lease_name: Optional[str] = None
        self._lock = threading.RLock()
        self.stats = {"chunks_written": 0, "chunks_referenced": 0,
                      "bytes_written": 0, "bytes_referenced": 0,
                      "chunks_removed": 0,
                      "bytes_uploaded": 0, "bytes_fetched": 0,
                      "round_trips": 0, "reconnects": 0}

    @property
    def spec(self) -> str:
        return make_spec(self.host, self.port, self.namespace)

    # --------------------------------------------------------------- wire
    def _conn(self) -> socket.socket:
        if self._sock is None or self._pid != os.getpid():
            try:
                s = socket.create_connection(
                    (self.host, self.port), timeout=self.connect_timeout)
            except OSError as e:
                raise ChunkServiceError(
                    f"chunk server {self.host}:{self.port} unreachable: "
                    f"{e}") from None
            s.settimeout(None)
            self._sock, self._pid = s, os.getpid()
        return self._sock

    def _request(self, cmds: Sequence[tuple]) -> list:
        attempts = max(1, int(tunables.CHUNK_RETRIES))
        with self._lock:
            for attempt in range(attempts):
                try:
                    blob = self._attempt(cmds)
                except ChunkServiceError:
                    # connection-layer failure — socket already closed by
                    # the attempt; re-dial after a jittered backoff
                    if attempt + 1 >= attempts:
                        raise
                    delay = tunables.CHUNK_RETRY_BASE_S * (2 ** attempt)
                    time.sleep(delay * (0.5 + random.random()))
                    self.stats["reconnects"] += 1
                    continue
                self.stats["round_trips"] += 1
                ok, payload = loads_body(blob)
                if not ok:
                    raise payload    # server-raised: healthy wire, no retry
                return payload

    def _attempt(self, cmds: Sequence[tuple]):
        s = self._conn()
        try:
            write_frame_parts(s, dumps_parts(
                (CHUNK_PROTOCOL_VERSION, self.namespace, list(cmds))))
            blob = read_frame_mv(s)
        except OSError as e:
            self.close()
            raise ChunkServiceError(
                f"chunk server {self.host}:{self.port} request "
                f"failed: {e}") from None
        if blob is None:
            self.close()
            raise ChunkServiceError(
                f"chunk server {self.host}:{self.port} closed the "
                f"connection mid-reply")
        return blob

    def _call(self, cmd: str, *args) -> Any:
        return self._request([(cmd, args)])[0]

    def close(self) -> None:
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None
                self._pid = None

    # ------------------------------------------------------------ backend
    def has(self, name: str) -> bool:
        return name in self._call("has_many", [name])

    def has_many(self, names: Sequence[str]) -> Dict[str, int]:
        return self._call("has_many", list(names))

    def size(self, name: str) -> int:
        return self._call("size", name)

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        present = self.has_many(names)
        return {n: present.get(n) for n in names}

    def get(self, name: str) -> bytes:
        # out-of-band replies arrive as a memoryview over the receive
        # buffer; the public API promises bytes
        blob = _as_bytes(self._call("get", name))
        self.stats["bytes_fetched"] += len(blob)
        return blob

    def get_many(self, names: Sequence[str]) -> Dict[str, bytes]:
        out = {n: _as_bytes(b)
               for n, b in self._call("get_many", list(names)).items()}
        self.stats["bytes_fetched"] += sum(len(b) for b in out.values())
        return out

    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        wrote = self._call("put", name, _oob(blob), raw_bytes)
        raw = raw_bytes or len(blob)
        if wrote:
            self.stats["chunks_written"] += 1
            self.stats["bytes_written"] += raw
            self.stats["bytes_uploaded"] += len(blob)
        else:
            self.stats["chunks_referenced"] += 1
            self.stats["bytes_referenced"] += raw
        return wrote

    def ref(self, name: str, raw_bytes: int) -> None:
        self._call("ref", name, raw_bytes)
        self.stats["chunks_referenced"] += 1
        self.stats["bytes_referenced"] += raw_bytes

    def list_chunks(self) -> Set[str]:
        return set(self._call("list"))

    def gc(self, live: Iterable[str]) -> int:
        """Removes nothing server-side (returns 0): a namespace may back
        several writers whose live sets this client cannot see, so the
        AUTOMATIC per-save gc a CheckpointManager runs must never unlink
        on the server.  It DOES register `live` as this client's TTL
        lease, so server reclamation — another writer's ``gc_remote`` or
        the server's auto-sweep — is safe without coordination.
        Best-effort: an outage mid-renewal is swallowed (the previous
        lease and the sweep grace window keep protecting until the
        server is back)."""
        try:
            self.lease(live)
        except (ChunkServiceError, OSError):
            pass
        return 0

    def gc_remote(self, live: Iterable[str]) -> int:
        """Explicit server-side GC-live-set — caller asserts it owns the
        namespace.  The server extends `live` with every unexpired lease,
        so even this cannot collect chunks other clients registered."""
        removed = self._call("gc", sorted(set(live)))
        self.stats["chunks_removed"] += removed
        return removed

    # -------------------------------------------------------------- leases
    def _lease_id(self) -> str:
        # pid-qualified and regenerated after fork: a forked child must
        # renew ITS OWN lease, not clobber the parent's live set
        if self._lease_name is None or self._lease_pid != os.getpid():
            self._lease_pid = os.getpid()
            self._lease_name = (
                f"client-{os.getpid()}-{os.urandom(3).hex()}")
        return self._lease_name

    def lease(self, names: Iterable[str], ttl: Optional[float] = None,
              lease_id: Optional[str] = None) -> int:
        """Register/renew a TTL lease over `names`: until expiry no
        server-side gc (explicit or auto-sweep) may collect them.  A
        migration pins each streamed round under its own ``lease_id``."""
        return self._call("lease", lease_id or self._lease_id(),
                          sorted(set(names)),
                          self.DEFAULT_LEASE_TTL if ttl is None else ttl)

    def unlease(self, lease_id: Optional[str] = None) -> bool:
        return self._call("unlease", lease_id or self._lease_id())

    def leases(self) -> dict:
        return self._call("leases")

    def server_stats(self) -> dict:
        return self._call("stats")


class CachingChunkStore(ChunkStoreBackend):
    """A local chunk cache layered over a ``RemoteChunkStore``.

    SAVE: ``has``/``has_many`` are answered by the SERVER (authoritative
    — another host's restore must be able to fetch every referenced
    chunk), one batched round trip per save; only missing chunks upload
    (``bytes_uploaded``), present ones are referenced
    (``bytes_referenced_remote``, server-side wire bytes).  Every put
    also lands in the cache, so the writing host restores locally.

    RESTORE: ``get`` is cache-first; a miss fetches from the server AND
    pins the blob into the cache (``bytes_fetched``), so the next restore
    of an overlapping manifest moves only what changed — the incremental
    property, now across hosts.

    GC collects the CACHE only (see module docstring for why); use
    ``gc_remote`` to reclaim the server when the caller owns the
    namespace."""

    wants_batched_has = True

    def __init__(self, cache_root: str | Path, remote: RemoteChunkStore):
        self.cache = ChunkStore(cache_root)
        self.remote = remote
        self.root = self.cache.root
        self._lock = threading.Lock()
        #: {name: server clen} for names the server is KNOWN to hold, and
        #: the set it is known NOT to hold (as of the last query) — both
        #: primed by has_many so the per-chunk puts/refs of a save ride
        #: the ONE batched round trip save_shards already paid.  A stale
        #: negative only costs a redundant idempotent upload; a positive
        #: stays valid as long as this client's live-set lease is renewed
        #: (chunks are immutable and leased chunks are never collected;
        #: gc_remote clears both memos).
        self._known_remote: Dict[str, int] = {}
        self._known_absent: set = set()
        self.stats = {"chunks_written": 0, "chunks_referenced": 0,
                      "bytes_written": 0, "bytes_referenced": 0,
                      "chunks_removed": 0,
                      "bytes_uploaded": 0, "bytes_referenced_remote": 0,
                      "bytes_fetched": 0, "bytes_read": 0,
                      "cache_hits": 0, "cache_misses": 0}

    @property
    def spec(self) -> str:
        return make_spec(self.remote.host, self.remote.port,
                         self.remote.namespace, self.cache.root)

    @property
    def fetch_spec(self) -> str:
        return self.remote.spec      # portable: no writer-local cache dir

    def close(self) -> None:
        self.remote.close()

    # -------------------------------------------------- presence (server)
    def _presence(self, name: str) -> Optional[int]:
        with self._lock:
            if name in self._known_remote:
                return self._known_remote[name]
            if name in self._known_absent:
                return None
        got = self.remote.has_many([name])
        with self._lock:
            self._known_remote.update(got)
            if name not in got:
                self._known_absent.add(name)
        return got.get(name)

    def has(self, name: str) -> bool:
        return self._presence(name) is not None

    def has_many(self, names: Sequence[str]) -> Dict[str, int]:
        with self._lock:
            known = {n: self._known_remote[n] for n in names
                     if n in self._known_remote}
            unknown = [n for n in names
                       if n not in known and n not in self._known_absent]
        if unknown:
            got = self.remote.has_many(unknown)
            with self._lock:
                self._known_remote.update(got)
                self._known_absent.update(n for n in unknown
                                          if n not in got)
            known.update(got)
        return known

    # ----------------------------------------------------- reads (cache)
    def size(self, name: str) -> int:
        if self.cache.has(name):
            return self.cache.size(name)
        clen = self._presence(name)
        if clen is None:
            raise FileNotFoundError(name)
        return clen

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        out: Dict[str, Optional[int]] = {}
        misses = []
        for n in names:
            if self.cache.has(n):
                out[n] = self.cache.size(n)
            else:
                misses.append(n)
        if misses:
            out.update(self.has_many(misses))
        return {n: out.get(n) for n in names}

    def get(self, name: str) -> bytes:
        if self.cache.has(name):
            blob = self.cache.get(name)
            with self._lock:
                self.stats["cache_hits"] += 1
                self.stats["bytes_read"] += len(blob)
            return blob
        blob = self.remote.get(name)
        self.cache.put(name, blob)          # pin: next restore is local
        with self._lock:
            self._known_remote.setdefault(name, len(blob))
            self.stats["cache_misses"] += 1
            self.stats["bytes_fetched"] += len(blob)
            self.stats["bytes_read"] += len(blob)
        return blob

    # ---------------------------------------------------- writes (server)
    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        raw = raw_bytes or len(blob)
        if not self.cache.has(name):
            self.cache.put(name, blob, raw_bytes=raw)
        clen = self._presence(name)
        if clen is not None:
            with self._lock:
                self.stats["chunks_referenced"] += 1
                self.stats["bytes_referenced"] += raw
                self.stats["bytes_referenced_remote"] += clen
            return False
        self.remote.put(name, blob, raw_bytes=raw)
        with self._lock:
            self._known_remote[name] = len(blob)
            self._known_absent.discard(name)
            self.stats["chunks_written"] += 1
            self.stats["bytes_written"] += raw
            self.stats["bytes_uploaded"] += len(blob)
        return True

    def ref(self, name: str, raw_bytes: int) -> None:
        # counters only — no wire: a 13-of-16 incremental save must not
        # pay 13 round trips to bump a server-side stat (pure
        # RemoteChunkStore clients still forward REF; server stats then
        # describe their traffic)
        clen = self._presence(name)
        with self._lock:
            self.stats["chunks_referenced"] += 1
            self.stats["bytes_referenced"] += raw_bytes
            self.stats["bytes_referenced_remote"] += clen or 0

    # -------------------------------------------------------------- admin
    def list_chunks(self) -> Set[str]:
        return self.cache.list_chunks() | self.remote.list_chunks()

    def gc(self, live: Iterable[str]) -> int:
        """Collect the CACHE only, and renew this client's server-side
        lease over `live` (best-effort — see RemoteChunkStore.gc)."""
        live = set(live)
        removed = self.cache.gc(live)
        try:
            self.remote.lease(live)
        except (ChunkServiceError, OSError):
            pass
        with self._lock:
            self.stats["chunks_removed"] += removed
        return removed

    def gc_remote(self, live: Iterable[str]) -> int:
        removed = self.remote.gc_remote(live)
        with self._lock:
            self._known_remote = {}
            self._known_absent = set()
        return removed

    def lease(self, names: Iterable[str], ttl: Optional[float] = None,
              lease_id: Optional[str] = None) -> int:
        return self.remote.lease(names, ttl, lease_id)

    def unlease(self, lease_id: Optional[str] = None) -> bool:
        return self.remote.unlease(lease_id)
