"""Cross-host chunk service — the networked half of the pluggable
checkpoint store (DESIGN.md §11).

The paper's proxy argument applied to STORAGE: checkpoint against a
stable interface (``ChunkStoreBackend``), not an implementation (a host's
filesystem).  PR 4 made every rank a process behind a socket; the chunk
directory was the last host-local assumption.  This module removes it:

  * ``ChunkServer`` — serves a backing ``ChunkStore`` over sockets,
    reusing the process-world framing (``transport.write_frame_parts``
    / ``read_frame_mv``: 8-byte length + scatter-gather pickle body)
    and the same versioned command-batch shape the proxy wire protocol
    uses.  Chunk blobs at or above ``_OOB_MIN`` travel as pickle
    protocol-5 out-of-band buffers: a PUT gathers header + blob straight
    from the caller's buffer into ``sendmsg`` and a GET reply is decoded
    as a view over the one receive buffer — no intermediate ``bytes``
    concatenation on either side, in either direction.  Commands:
    HAS-many, PUT, GET(-many), REF, GC-live-set, SIZE, LIST, STATS.
    A request frame is read IN FULL before anything is applied, and the
    backing store commits with tmp-file + atomic rename — so a client
    SIGKILLed mid-upload (a torn frame, read as EOF) can never leave a
    partial chunk visible to ``has()``.
  * ``RemoteChunkStore`` — the client backend.  Connects lazily and
    re-connects after a fork (rank children each get their own socket),
    one request/reply cycle per call under a lock.
  * ``CachingChunkStore`` — a local ``ChunkStore`` cache layered over a
    remote.  Saves upload only chunks the SERVER doesn't have (batched
    HAS before upload); restores fetch only chunks the CACHE doesn't
    have and pin them locally — a restart on a fresh host (empty cache
    dir) transfers exactly the missing bytes.

Coherence story: chunks are immutable and content-addressed, so cache
and server can never disagree about a name's bytes — the only states are
"absent" and "identical".  The asymmetric views follow from that:
``has``/``has_many`` answer for the SERVER (the upload decision must be
authoritative for other hosts' restores), ``get``/``sizes`` answer
cache-first (reads want the nearest copy).  ``gc`` collects the CACHE
only — a server may back several writers whose live sets the client
can't see — but it also REGISTERS the caller's live set as a TTL
**lease** on the server, which makes server-side reclamation safe
without coordination: the explicit ``gc_remote`` and the server's own
optional auto-sweep both refuse to collect any chunk covered by an
unexpired lease, and the sweep additionally spares chunks younger than
a grace window (covering the upload→lease gap — a migration round
streamed but not yet committed can never be collected mid-flight).

Namespaces: a server partitions its root per namespace (one flat chunk
dir each), so independent jobs sharing one server cannot observe each
other through dedup or collect each other's chunks.

SCALE-OUT (PR 9, DESIGN.md §15): ``ShardedChunkStore`` runs one
``RemoteChunkStore`` client per server and digest-space-partitions the
chunk namespace across them — the content-addressed name IS the
placement key, so the shard map is a pure function and needs no
directory service.  Each chunk is written to R consecutive shards
(replicas); reads fail over along the same ring, so a killed or bounced
server degrades the store instead of failing it.  Batched queries
(``has_many``/``get_many``) split per shard and fan out on a bounded
pool — the restore working set arrives over N sockets concurrently.

Spec grammar (``chunkstore.StoreSpec`` — the one canonical form):

    remote://HOST:PORT[/NAMESPACE][?cache=DIR]
    remote://H1:P1,H2:P2,H3:P3[/NAMESPACE][?cache=DIR&replicas=R]
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import pickle
import random
import socket
import struct
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.checkpoint.chunkstore import (ChunkStore, ChunkStoreBackend,
                                         StoreSpec, check_token)
from repro.core import trace as _trace
from repro.core import tunables
from repro.core.transport import (dumps_parts, loads_body, read_frame_mv,
                                  write_frame_parts)

#: versioned command batches, like the proxy wire protocol: a request is
#: ``(CHUNK_PROTOCOL_VERSION, namespace, [(cmd, args), ...])`` and the
#: reply is ``(True, [result, ...])`` or ``(False, exception)``.  Still
#: v1: the SG body encoding is self-describing (``loads_body`` accepts
#: both plain-pickle and SG bodies), so the frame change needs no bump.
CHUNK_PROTOCOL_VERSION = 1

#: blobs at least this large ride out-of-band (``pickle.PickleBuffer``)
#: in both directions; below it the plain in-band pickle is cheaper than
#: an extra iovec entry (REPRO_CHUNK_OOB_MIN)
_OOB_MIN = tunables.CHUNK_OOB_MIN


def _oob(blob) -> Any:
    """Large blobs as zero-copy out-of-band buffers, small ones as bytes.
    The receiving side sees a memoryview over its single receive buffer
    for the former — ``_as_bytes`` converts at the API boundary."""
    if len(blob) >= _OOB_MIN:
        return pickle.PickleBuffer(blob)
    return bytes(blob)


def _as_bytes(blob) -> bytes:
    return blob if isinstance(blob, bytes) else bytes(blob)


class ChunkServiceError(ConnectionError):
    """Chunk-service wire failure (torn reply, refused connection,
    protocol mismatch).  A ConnectionError subclass so every existing
    ``except OSError`` around restore/validate treats an unreachable
    server exactly like a missing local file."""


#: chunk names, namespaces and lease ids are digest-shaped tokens;
#: anything else is rejected server-side (a name is used as a path
#: component).  One validator, shared with StoreSpec (chunkstore.py).
_check_token = check_token


def _split_endpoint(endpoint: str) -> Tuple[str, int]:
    host, _, port = endpoint.rpartition(":")
    return host, int(port)


def parse_spec(spec: str) -> Tuple[str, int, str, Optional[str]]:
    """Back-compat view of ``StoreSpec.parse`` for SINGLE-endpoint specs:
    ``remote://host:port[/ns][?cache=DIR]`` -> (host, port, ns, cache).
    Sharded (multi-endpoint) specs don't fit a 4-tuple — parse those with
    ``StoreSpec.parse`` and read ``.endpoints``/``.replicas``."""
    if not str(spec).startswith("remote://"):
        raise ValueError(f"not a remote chunk-store spec: {spec!r}")
    sp = StoreSpec.parse(spec)
    if sp.sharded:
        raise ValueError(
            f"parse_spec is single-endpoint; {spec!r} is sharded — "
            f"use StoreSpec.parse")
    host, port = _split_endpoint(sp.endpoints[0])
    return host, port, sp.namespace, sp.cache


def make_spec(host: str, port: int, namespace: str = "",
              cache: Optional[str | Path] = None) -> str:
    """Canonical single-endpoint spec string (``StoreSpec.canonical``)."""
    return StoreSpec(scheme="remote", endpoints=(f"{host}:{int(port)}",),
                     namespace=namespace,
                     cache=str(cache) if cache else None).canonical()


def store_from_spec(spec: str | StoreSpec) -> ChunkStoreBackend:
    """Build the client backend a remote ``StoreSpec`` describes: one
    ``RemoteChunkStore`` per endpoint — behind a ``ShardedChunkStore``
    when there are several — wrapped in a ``CachingChunkStore`` when the
    spec carries a cache directory."""
    sp = StoreSpec.parse(spec)
    if sp.scheme != "remote":
        raise ValueError(f"not a remote chunk-store spec: {spec!r}")
    if sp.sharded:
        remote: ChunkStoreBackend = ShardedChunkStore(
            sp.endpoints, namespace=sp.namespace, replicas=sp.replicas)
    else:
        host, port = _split_endpoint(sp.endpoints[0])
        remote = RemoteChunkStore(host, port, namespace=sp.namespace)
    if sp.cache is None:
        return remote
    return CachingChunkStore(sp.cache, remote)


# =========================================================================
# server
# =========================================================================

class ChunkServer:
    """Serve a directory of content-addressed chunks over sockets.

    One accept thread + one thread per connection (rank children, writer
    pools and restore pools each hold their own connection).  The backing
    ``ChunkStore`` is thread-safe and its writes are atomic renames, so
    concurrent PUTs of the same digest collapse to one file — the same
    idempotence the local store gives racing processes.

    GC LEASES: clients register their live chunk sets under named TTL
    leases (``lease``/``unlease`` commands; renewed automatically by every
    client-side gc round).  The GC-live-set command then treats the union
    of unexpired leases as live IN ADDITION to the caller's set, so one
    writer's reclamation can never collect another's chunks — and a
    migration pins each streamed-but-uncommitted round under its own
    lease.  With ``auto_gc_interval`` set, the server also sweeps on its
    own: a chunk is collected only when NO unexpired lease covers it AND
    it is older than ``gc_grace`` seconds (the grace spares the
    upload→lease gap of an in-flight save).
    """

    def __init__(self, root: str | Path, host: str = "127.0.0.1",
                 port: int = 0, advertise_host: Optional[str] = None,
                 auto_gc_interval: Optional[float] = None,
                 gc_grace: float = 60.0):
        self.root = Path(root)
        self.auto_gc_interval = auto_gc_interval
        self.gc_grace = gc_grace
        self._stores: Dict[str, ChunkStore] = {}
        #: {namespace: {lease_id: (monotonic expiry, frozenset(names))}}
        self._leases: Dict[str, Dict[str, Tuple[float, frozenset]]] = {}
        self._lock = threading.Lock()
        self._srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._srv.bind((host, port))
        self._srv.listen(64)
        self._srv.settimeout(0.2)
        bound_host, self.port = self._srv.getsockname()[:2]
        # specs must carry an address CLIENTS can dial: a wildcard bind
        # ("0.0.0.0"/"::") is not one — cross-host deployments pass the
        # reachable name via advertise_host
        self.host = advertise_host or bound_host
        if self.host in ("0.0.0.0", "::"):
            self.host = socket.gethostname()
        self._halt = threading.Event()
        self._threads: List[threading.Thread] = []
        self._conns: List[socket.socket] = []
        self._accept: Optional[threading.Thread] = None
        self._sweeper: Optional[threading.Thread] = None

    @property
    def spec(self) -> str:
        return make_spec(self.host, self.port)

    def spec_for(self, namespace: str = "",
                 cache: Optional[str | Path] = None) -> str:
        return make_spec(self.host, self.port, namespace, cache)

    def backing(self, namespace: str = "") -> ChunkStore:
        """The per-namespace backing store (the server's own view — tests
        and ops poke it directly)."""
        if namespace:
            _check_token(namespace, "namespace")
        with self._lock:
            st = self._stores.get(namespace)
            if st is None:
                st = ChunkStore(self.root / namespace if namespace
                                else self.root)
                self._stores[namespace] = st
        return st

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ChunkServer":
        self._accept = threading.Thread(target=self._accept_loop,
                                        daemon=True, name="chunk-server")
        self._accept.start()
        if self.auto_gc_interval:
            self._sweeper = threading.Thread(target=self._sweep_loop,
                                             daemon=True,
                                             name="chunk-server-gc")
            self._sweeper.start()
        return self

    def stop(self, join_timeout: float = 5.0) -> None:
        self._halt.set()
        try:
            self._srv.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                c.close()
            except OSError:
                pass
        if self._accept is not None:
            self._accept.join(join_timeout)
        if self._sweeper is not None:
            self._sweeper.join(join_timeout)
        with self._lock:
            threads = list(self._threads)
        for t in threads:
            if t is not threading.current_thread():
                t.join(join_timeout)

    def __enter__(self) -> "ChunkServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # ------------------------------------------------------------- serving
    def _accept_loop(self) -> None:
        while not self._halt.is_set():
            try:
                conn, _ = self._srv.accept()
            except socket.timeout:
                continue
            except OSError:          # server socket closed by stop()
                return
            t = threading.Thread(target=self._serve, args=(conn,),
                                 daemon=True, name="chunk-server-conn")
            with self._lock:
                self._conns.append(conn)
                self._threads.append(t)
            t.start()

    def _serve(self, conn: socket.socket) -> None:
        """One connection: read a WHOLE request frame, apply, reply.  A
        torn frame (client died mid-send) reads as EOF — the half-shipped
        PUT is dropped on the floor, never applied."""
        try:
            while not self._halt.is_set():
                blob = read_frame_mv(conn)
                if blob is None:
                    return
                try:
                    version, ns, cmds = loads_body(blob)
                    if version != CHUNK_PROTOCOL_VERSION:
                        raise ChunkServiceError(
                            f"client speaks chunk protocol v{version}, "
                            f"server v{CHUNK_PROTOCOL_VERSION}")
                    store = self.backing(ns)
                    with _trace.span(
                            "chunkserver.req", cat="chunkservice",
                            args={"ns": ns, "n": len(cmds),
                                  "cmd": cmds[0][0] if cmds else None}):
                        results = [self._execute(ns, store, cmd, args)
                                   for cmd, args in cmds]
                    reply = (True, results)
                except Exception as e:      # noqa: BLE001 - shipped back
                    reply = (False, e)
                write_frame_parts(conn, dumps_parts(reply))
        except (OSError, pickle.PickleError):
            return
        finally:
            try:
                conn.close()
            except OSError:
                pass
            # prune: a long-lived server sheds each disconnected client
            # (one socket per rank child / pool — they come and go)
            me = threading.current_thread()
            with self._lock:
                if conn in self._conns:
                    self._conns.remove(conn)
                if me in self._threads:
                    self._threads.remove(me)

    # --------------------------------------------------------------- leases
    def _lease_union(self, namespace: str) -> Set[str]:
        """Union of chunk names covered by unexpired leases in the
        namespace; expired leases are pruned as a side effect."""
        now = time.monotonic()
        out: Set[str] = set()
        with self._lock:
            table = self._leases.get(namespace)
            if not table:
                return out
            for lid in [k for k, (exp, _) in table.items() if exp < now]:
                del table[lid]
            for _, names in table.values():
                out.update(names)
        return out

    def sweep(self, grace: Optional[float] = None) -> int:
        """Server-initiated reclamation across every namespace touched so
        far: remove chunks covered by NO unexpired lease and older than
        ``grace`` seconds (file mtime).  The grace window protects chunks
        a client has uploaded but not yet covered with a lease or a
        committed manifest — mid-save and mid-migration-round state.
        Runs periodically when ``auto_gc_interval`` is set; callable
        directly for deterministic tests/ops."""
        grace = self.gc_grace if grace is None else grace
        cutoff = time.time() - grace
        removed_total = 0
        with self._lock:
            spaces = list(self._stores.items())
        for ns, store in spaces:
            protected = self._lease_union(ns)
            removed = 0
            for name in store.list_chunks():
                if name in protected:
                    continue
                p = store.root / name
                try:
                    if p.stat().st_mtime > cutoff:
                        continue
                    p.unlink()
                    removed += 1
                except OSError:
                    continue
            if removed:
                with store._lock:
                    store.stats["chunks_removed"] += removed
            removed_total += removed
        return removed_total

    def _sweep_loop(self) -> None:
        while not self._halt.wait(self.auto_gc_interval):
            try:
                self.sweep()
            except Exception:       # noqa: BLE001 - sweep must never die
                pass

    def _execute(self, ns: str, store: ChunkStore, cmd: str,
                 args: tuple) -> Any:
        if cmd == "has_many":
            (names,) = args
            out: Dict[str, int] = {}
            for n in names:
                _check_token(n, "chunk name")
                if store.has(n):
                    out[n] = store.size(n)
            return out
        if cmd == "put":
            name, blob, raw = args
            _check_token(name, "chunk name")
            # blob may be a memoryview over the request's receive buffer
            # (out-of-band PUT); the store writes any buffer object
            return store.put(name, blob, raw_bytes=raw)
        if cmd == "get":
            (name,) = args
            _check_token(name, "chunk name")
            return _oob(store.get(name))
        if cmd == "get_many":
            (names,) = args
            out = {}
            for n in names:
                _check_token(n, "chunk name")
                if store.has(n):
                    out[n] = _oob(store.get(n))
            return out
        if cmd == "ref":
            name, raw = args
            store.ref(name, raw)
            return None
        if cmd == "gc":
            # the caller's live set PLUS every unexpired lease: explicit
            # reclamation by one writer can never collect chunks another
            # client has registered as live
            (live,) = args
            return store.gc(set(live) | self._lease_union(ns))
        if cmd == "lease":
            lease_id, names, ttl = args
            _check_token(lease_id, "lease id")
            names = frozenset(names)
            for n in names:
                _check_token(n, "chunk name")
            with self._lock:
                self._leases.setdefault(ns, {})[lease_id] = (
                    time.monotonic() + float(ttl), names)
            return len(names)
        if cmd == "unlease":
            (lease_id,) = args
            with self._lock:
                table = self._leases.get(ns, {})
                return table.pop(lease_id, None) is not None
        if cmd == "leases":
            now = time.monotonic()
            with self._lock:
                table = dict(self._leases.get(ns, {}))
            return {lid: {"ttl": exp - now, "chunks": len(names)}
                    for lid, (exp, names) in table.items() if exp >= now}
        if cmd == "size":
            (name,) = args
            _check_token(name, "chunk name")
            return store.size(name)
        if cmd == "list":
            return sorted(store.list_chunks())
        if cmd == "stats":
            return dict(store.stats)
        raise ValueError(f"unknown chunk-service command {cmd!r}")


# =========================================================================
# client backends
# =========================================================================

class RemoteChunkStore(ChunkStoreBackend):
    """Socket client to a ``ChunkServer`` — a pure remote backend.

    Fork-safe by construction: the connection is opened lazily and keyed
    to the owning pid, so a forked rank child that inherited this object
    transparently opens its OWN socket instead of interleaving frames on
    the parent's.  One request/reply cycle at a time under a lock (the
    writer pool serializes here; the server side fans out per
    connection, so parallel clients scale, parallel calls on ONE client
    pipeline through one socket).

    Connection-layer failures (dial refused, torn write/read, EOF
    mid-reply) are retried up to ``REPRO_CHUNK_RETRIES`` attempts with
    doubling, jittered backoff from ``REPRO_CHUNK_RETRY_BASE_S`` — a
    chunk server bounced under the client (crash + restart, rolling
    upgrade) costs a short stall instead of a failed checkpoint.  Whole
    requests are replayed: every command is idempotent (content-addressed
    PUT, read-only GET/HAS, set-valued REF/LEASE), so a reply lost on the
    wire re-executes safely.  Errors the SERVER raised are never retried
    — those arrive on a healthy round trip and retrying cannot change
    them."""

    wants_batched_has = True
    root = None

    #: default TTL for the client's automatic live-set lease — long
    #: enough to bridge several save/gc rounds, short enough that a dead
    #: client's pin drains away on its own (REPRO_CHUNK_LEASE_TTL_S)
    DEFAULT_LEASE_TTL = tunables.CHUNK_LEASE_TTL_S

    def __init__(self, host: str, port: int, namespace: str = "",
                 connect_timeout: float = 10.0):
        self.host, self.port = host, int(port)
        self.namespace = namespace
        if namespace:
            _check_token(namespace, "namespace")
        self.connect_timeout = connect_timeout
        self._sock: Optional[socket.socket] = None
        self._pid: Optional[int] = None
        self._lease_pid: Optional[int] = None
        self._lease_name: Optional[str] = None
        self._lock = threading.RLock()
        self.stats = {"chunks_written": 0, "chunks_referenced": 0,
                      "bytes_written": 0, "bytes_referenced": 0,
                      "chunks_removed": 0,
                      "bytes_uploaded": 0, "bytes_fetched": 0,
                      "round_trips": 0, "reconnects": 0}

    @property
    def endpoint(self) -> str:
        return f"{self.host}:{self.port}"

    @property
    def spec_obj(self) -> StoreSpec:
        return StoreSpec(scheme="remote", endpoints=(self.endpoint,),
                         namespace=self.namespace)

    # --------------------------------------------------------------- wire
    def _conn(self) -> socket.socket:
        if self._sock is None or self._pid != os.getpid():
            try:
                s = socket.create_connection(
                    (self.host, self.port), timeout=self.connect_timeout)
            except OSError as e:
                raise ChunkServiceError(
                    f"chunk server {self.host}:{self.port} unreachable: "
                    f"{e}") from None
            s.settimeout(None)
            self._sock, self._pid = s, os.getpid()
        return self._sock

    def _request(self, cmds: Sequence[tuple]) -> list:
        attempts = max(1, int(tunables.CHUNK_RETRIES))
        # chunk.rpc span: thread-local parenting nests it under whatever
        # span issued the store call — a rank child's rank.save_image, the
        # driver's ckptmgr.write — so uploads land on the save's timeline
        with _trace.span("chunk.rpc", cat="chunk",
                         args={"n": len(cmds),
                               "cmd": cmds[0][0] if cmds else None}), \
                self._lock:
            for attempt in range(attempts):
                try:
                    blob = self._attempt(cmds)
                except ChunkServiceError:
                    # connection-layer failure — socket already closed by
                    # the attempt; re-dial after a jittered backoff
                    if attempt + 1 >= attempts:
                        raise
                    delay = tunables.CHUNK_RETRY_BASE_S * (2 ** attempt)
                    time.sleep(delay * (0.5 + random.random()))
                    self.stats["reconnects"] += 1
                    continue
                self.stats["round_trips"] += 1
                ok, payload = loads_body(blob)
                if not ok:
                    raise payload    # server-raised: healthy wire, no retry
                return payload

    def _attempt(self, cmds: Sequence[tuple]):
        s = self._conn()
        try:
            write_frame_parts(s, dumps_parts(
                (CHUNK_PROTOCOL_VERSION, self.namespace, list(cmds))))
            blob = read_frame_mv(s)
        except OSError as e:
            self.close()
            raise ChunkServiceError(
                f"chunk server {self.host}:{self.port} request "
                f"failed: {e}") from None
        if blob is None:
            self.close()
            raise ChunkServiceError(
                f"chunk server {self.host}:{self.port} closed the "
                f"connection mid-reply")
        return blob

    def _call(self, cmd: str, *args) -> Any:
        return self._request([(cmd, args)])[0]

    def close(self) -> None:
        with self._lock:
            if self._sock is not None:
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None
                self._pid = None

    # ------------------------------------------------------------ backend
    def has(self, name: str) -> bool:
        return name in self._call("has_many", [name])

    def has_many(self, names: Sequence[str]) -> Dict[str, int]:
        return self._call("has_many", list(names))

    def size(self, name: str) -> int:
        return self._call("size", name)

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        present = self.has_many(names)
        return {n: present.get(n) for n in names}

    def get(self, name: str) -> bytes:
        # out-of-band replies arrive as a memoryview over the receive
        # buffer; the public API promises bytes
        blob = _as_bytes(self._call("get", name))
        self.stats["bytes_fetched"] += len(blob)
        return blob

    def get_many(self, names: Sequence[str]) -> Dict[str, bytes]:
        out = {n: _as_bytes(b)
               for n, b in self._call("get_many", list(names)).items()}
        self.stats["bytes_fetched"] += sum(len(b) for b in out.values())
        return out

    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        wrote = self._call("put", name, _oob(blob), raw_bytes)
        raw = raw_bytes or len(blob)
        if wrote:
            self.stats["chunks_written"] += 1
            self.stats["bytes_written"] += raw
            self.stats["bytes_uploaded"] += len(blob)
        else:
            self.stats["chunks_referenced"] += 1
            self.stats["bytes_referenced"] += raw
        return wrote

    def ref(self, name: str, raw_bytes: int) -> None:
        self._call("ref", name, raw_bytes)
        self.stats["chunks_referenced"] += 1
        self.stats["bytes_referenced"] += raw_bytes

    def list_chunks(self) -> Set[str]:
        return set(self._call("list"))

    def gc(self, live: Iterable[str]) -> int:
        """Removes nothing server-side (returns 0): a namespace may back
        several writers whose live sets this client cannot see, so the
        AUTOMATIC per-save gc a CheckpointManager runs must never unlink
        on the server.  It DOES register `live` as this client's TTL
        lease, so server reclamation — another writer's ``gc_remote`` or
        the server's auto-sweep — is safe without coordination.
        Best-effort: an outage mid-renewal is swallowed (the previous
        lease and the sweep grace window keep protecting until the
        server is back)."""
        try:
            self.lease(live)
        except (ChunkServiceError, OSError):
            pass
        return 0

    def gc_remote(self, live: Iterable[str]) -> int:
        """Explicit server-side GC-live-set — caller asserts it owns the
        namespace.  The server extends `live` with every unexpired lease,
        so even this cannot collect chunks other clients registered."""
        removed = self._call("gc", sorted(set(live)))
        self.stats["chunks_removed"] += removed
        return removed

    # -------------------------------------------------------------- leases
    def _lease_id(self) -> str:
        # pid-qualified and regenerated after fork: a forked child must
        # renew ITS OWN lease, not clobber the parent's live set
        if self._lease_name is None or self._lease_pid != os.getpid():
            self._lease_pid = os.getpid()
            self._lease_name = (
                f"client-{os.getpid()}-{os.urandom(3).hex()}")
        return self._lease_name

    def lease(self, names: Iterable[str], ttl: Optional[float] = None,
              lease_id: Optional[str] = None) -> int:
        """Register/renew a TTL lease over `names`: until expiry no
        server-side gc (explicit or auto-sweep) may collect them.  A
        migration pins each streamed round under its own ``lease_id``."""
        return self._call("lease", lease_id or self._lease_id(),
                          sorted(set(names)),
                          self.DEFAULT_LEASE_TTL if ttl is None else ttl)

    def unlease(self, lease_id: Optional[str] = None) -> bool:
        return self._call("unlease", lease_id or self._lease_id())

    def leases(self) -> dict:
        return self._call("leases")

    def server_stats(self) -> dict:
        return self._call("stats")


class ShardedChunkStore(ChunkStoreBackend):
    """Digest-space sharding + replication across N ``ChunkServer``s —
    the checkpoint CDN tier (DESIGN.md §15).

    PLACEMENT is a pure function of the content-addressed name: the hex
    digest prefix mod the shard count picks the HOME shard, and a chunk's
    replica set is the R consecutive shards starting there (a ring walk).
    blake2b output is uniform, so shards stay balanced with no directory
    service, no rebalancer, and no extra metadata — any client that
    knows the endpoint list (the StoreSpec) can compute where every
    chunk lives.  The endpoint ORDER is the shard map: permuting it is a
    different store.

    WRITE path: ``put`` offers the blob to each replica in ring order; a
    put succeeds when at least ONE replica accepts (``degraded_puts``
    counts saves that landed under-replicated).  Each shard client keeps
    the PR-8 retry/backoff ladder, so a bounced server stalls briefly
    and a dead one is marked DOWN for ``REPRO_SHARD_RETRY_S`` — later
    ops skip it (one probe re-tests after the cooldown) instead of
    re-paying the ladder per chunk.

    READ path: ``get`` walks the same ring and fails over past dead or
    chunk-less replicas (``failover_reads``); batched ``has_many`` /
    ``get_many`` split the name list per shard and fan out on a bounded
    pool (``REPRO_SHARD_FANOUT``) — a restore working set streams over N
    sockets concurrently, which is where the wire-time win comes from.

    SEMANTICS under partial outage follow the gc-safety rule: presence
    queries (``has_many``, the upload decision) treat an unreachable
    shard as "not holding anything" — the worst case is a redundant
    idempotent re-upload — while ``sizes`` (the validate/restore view)
    RAISES when a name is unresolved and any of its replicas was
    unreachable, because "can't tell" must never read as "definitely
    missing".  Leases and gc fan out to every shard; ``gc`` stays
    lease-only like the single-server client.

    Fork-safe like ``RemoteChunkStore``: each shard client re-dials
    after a fork, and the fan-out pool is lazily rebuilt per pid."""

    wants_batched_has = True
    root = None

    def __init__(self, endpoints: Sequence[str], namespace: str = "",
                 replicas: Optional[int] = None,
                 connect_timeout: float = 10.0):
        self.endpoints = tuple(endpoints)
        if not self.endpoints:
            raise ValueError("sharded store needs at least one endpoint")
        self.namespace = namespace
        want = tunables.SHARD_REPLICAS if replicas is None else int(replicas)
        self.replicas = max(1, min(want, len(self.endpoints)))
        self.shards = [
            RemoteChunkStore(*_split_endpoint(ep), namespace=namespace,
                             connect_timeout=connect_timeout)
            for ep in self.endpoints]
        #: {shard idx: monotonic time it was marked down}
        self._down: Dict[int, float] = {}
        self._probing: Set[int] = set()
        self._lock = threading.Lock()
        self._exec: Optional[cf.ThreadPoolExecutor] = None
        self._exec_pid: Optional[int] = None
        self.stats = {"chunks_written": 0, "chunks_referenced": 0,
                      "bytes_written": 0, "bytes_referenced": 0,
                      "chunks_removed": 0,
                      "bytes_uploaded": 0, "bytes_fetched": 0,
                      "degraded_puts": 0, "failover_reads": 0,
                      "shard_errors": 0, "shards_down": 0,
                      "shards": len(self.endpoints),
                      "replicas": self.replicas}

    @property
    def spec_obj(self) -> StoreSpec:
        # resolved (explicit, clamped) replica count: a manifest written
        # under REPRO_REPLICAS=3 must restore identically elsewhere
        return StoreSpec(scheme="remote", endpoints=self.endpoints,
                         namespace=self.namespace, replicas=self.replicas)

    def close(self) -> None:
        for sh in self.shards:
            sh.close()
        with self._lock:
            if self._exec is not None:
                self._exec.shutdown(wait=False)
                self._exec = None
                self._exec_pid = None

    # ---------------------------------------------------------- placement
    def _home(self, name: str) -> int:
        stem = name.split(".", 1)[0]
        try:
            return int(stem[:15], 16) % len(self.shards)
        except ValueError:
            # non-digest name (shouldn't happen on the save path, but
            # reads of foreign names must still route deterministically)
            return zlib.crc32(name.encode()) % len(self.shards)

    def _replica_ids(self, name: str) -> List[int]:
        h, n = self._home(name), len(self.shards)
        return [(h + k) % n for k in range(self.replicas)]

    # ----------------------------------------------------- shard plumbing
    def _usable(self, i: int) -> bool:
        """False while shard `i` is inside its mark-down cooldown.  After
        the cooldown ONE caller gets a True (the probe); everyone else
        keeps skipping until the probe's verdict lands."""
        with self._lock:
            t = self._down.get(i)
            if t is None:
                return True
            if (time.monotonic() - t >= tunables.SHARD_RETRY_S
                    and i not in self._probing):
                self._probing.add(i)
                return True
            return False

    def _mark_up(self, i: int) -> None:
        with self._lock:
            self._down.pop(i, None)
            self._probing.discard(i)
            self.stats["shards_down"] = len(self._down)

    def _mark_down(self, i: int) -> None:
        with self._lock:
            self._down[i] = time.monotonic()
            self._probing.discard(i)
            self.stats["shard_errors"] += 1
            self.stats["shards_down"] = len(self._down)

    def _try(self, i: int, fn, *args):
        """One shard call with health accounting: a connection-layer
        failure (the client's whole retry ladder exhausted) marks the
        shard down; any answer — including a server-raised error —
        marks it up (the wire is healthy)."""
        try:
            out = fn(*args)
        except ChunkServiceError:
            self._mark_down(i)
            raise
        except Exception:
            self._mark_up(i)
            raise
        self._mark_up(i)
        return out

    def _pool(self) -> cf.ThreadPoolExecutor:
        with self._lock:
            if self._exec is None or self._exec_pid != os.getpid():
                # a forked child must not share the parent's pool threads
                self._exec = cf.ThreadPoolExecutor(
                    max_workers=max(1, min(tunables.SHARD_FANOUT,
                                           len(self.shards))),
                    thread_name_prefix="shard-fanout")
                self._exec_pid = os.getpid()
            return self._exec

    def _fanout(self, jobs: List[tuple]) -> List[tuple]:
        """Run ``[(shard idx, fn, args), ...]`` concurrently (each shard
        client still serializes on its own socket); returns
        ``[(idx, result-or-exception), ...]``."""
        if len(jobs) <= 1:
            out = []
            for i, fn, args in jobs:
                try:
                    out.append((i, self._try(i, fn, *args)))
                except Exception as e:      # noqa: BLE001 - sorted by caller
                    out.append((i, e))
            return out
        pool = self._pool()
        futs = [(i, pool.submit(self._try, i, fn, *args))
                for i, fn, args in jobs]
        out = []
        for i, f in futs:
            try:
                out.append((i, f.result()))
            except Exception as e:          # noqa: BLE001 - sorted by caller
                out.append((i, e))
        return out

    def _group_by_replicas(self, names: Sequence[str]) -> Dict[int, List[str]]:
        groups: Dict[int, List[str]] = {}
        for n in names:
            for i in self._replica_ids(n):
                groups.setdefault(i, []).append(n)
        return groups

    # ------------------------------------------------------------ presence
    def _presence(self, names: Sequence[str]):
        """({name: size} union over reachable replicas,
        {unreachable shard ids})."""
        groups = self._group_by_replicas(names)
        jobs, unreachable = [], set()
        for i, batch in groups.items():
            if self._usable(i):
                jobs.append((i, self.shards[i].has_many, (batch,)))
            else:
                unreachable.add(i)
        present: Dict[str, int] = {}
        for i, res in self._fanout(jobs):
            if isinstance(res, ChunkServiceError):
                unreachable.add(i)
            elif isinstance(res, Exception):
                raise res
            else:
                for n, sz in res.items():
                    present.setdefault(n, sz)
        return present, unreachable

    def has(self, name: str) -> bool:
        return name in self.has_many([name])

    def has_many(self, names: Sequence[str]) -> Dict[str, int]:
        # a chunk is present if ANY replica has it; an unreachable shard
        # contributes nothing — the upload decision then errs toward
        # re-uploading, which is idempotent and safe
        present, _ = self._presence(list(names))
        return present

    def size(self, name: str) -> int:
        sz = self.sizes([name]).get(name)
        if sz is None:
            raise FileNotFoundError(name)
        return sz

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        names = list(names)
        present, unreachable = self._presence(names)
        out = {n: present.get(n) for n in names}
        if unreachable:
            at_risk = [n for n in names if out[n] is None
                       and any(i in unreachable
                               for i in self._replica_ids(n))]
            if at_risk:
                eps = ",".join(self.shards[i].endpoint
                               for i in sorted(unreachable))
                raise ChunkServiceError(
                    f"cannot resolve {len(at_risk)} chunk(s): replica "
                    f"shard(s) {eps} unreachable")
        return out

    # --------------------------------------------------------------- reads
    def get(self, name: str) -> bytes:
        order = self._replica_ids(name)
        live = [i for i in order if self._usable(i)]
        down = [i for i in order if i not in live]
        last: Optional[Exception] = None
        # marked-down replicas go last: better one retry-ladder stall
        # against a possibly-stale mark than a false "unavailable"
        for i in live + down:
            try:
                blob = self._try(i, self.shards[i].get, name)
            except (OSError, KeyError) as e:
                last = e
                continue
            blob = _as_bytes(blob)
            with self._lock:
                self.stats["bytes_fetched"] += len(blob)
                if i != order[0]:
                    self.stats["failover_reads"] += 1
            return blob
        raise last if last is not None else FileNotFoundError(name)

    def get_many(self, names: Sequence[str]) -> Dict[str, bytes]:
        names = list(names)
        # primary assignment: each name to its first LIVE replica, so the
        # batches are disjoint and stream over N sockets concurrently
        usable: Dict[int, bool] = {}
        batches: Dict[int, List[str]] = {}
        for n in names:
            for i in self._replica_ids(n):
                if i not in usable:
                    usable[i] = self._usable(i)
                if usable[i]:
                    batches.setdefault(i, []).append(n)
                    break
        out: Dict[str, bytes] = {}
        jobs = [(i, self.shards[i].get_many, (batch,))
                for i, batch in batches.items()]
        for i, res in self._fanout(jobs):
            if isinstance(res, Exception):
                if not isinstance(res, (OSError, KeyError)):
                    raise res
                continue        # whole batch fails over below
            for n, b in res.items():
                b = _as_bytes(b)
                out[n] = b
                with self._lock:
                    self.stats["bytes_fetched"] += len(b)
        # failover: anything a primary didn't deliver (shard died
        # mid-call, or holds no copy) walks the per-name replica ladder;
        # names absent EVERYWHERE are omitted, like the server command
        for n in names:
            if n not in out:
                try:
                    out[n] = self.get(n)
                except (OSError, KeyError):
                    pass
        return out

    # -------------------------------------------------------------- writes
    def put(self, name: str, blob, raw_bytes: int = 0) -> bool:
        raw = raw_bytes or len(blob)
        order = self._replica_ids(name)
        live = [i for i in order if self._usable(i)]
        down = [i for i in order if i not in live]
        wrote_n = 0
        landed = 0          # replicas holding the bytes after this call
        referenced = False
        errors: List[Exception] = []
        for i in live:
            try:
                if self._try(i, self.shards[i].put, name, blob, raw_bytes):
                    wrote_n += 1
                else:
                    referenced = True
                landed += 1
            except (ChunkServiceError, OSError) as e:
                errors.append(e)
        if landed == 0:
            # nothing landed on a live replica: probe the marked-down
            # ones before declaring the save degraded past saving
            for i in down:
                try:
                    if self._try(i, self.shards[i].put,
                                 name, blob, raw_bytes):
                        wrote_n += 1
                    else:
                        referenced = True
                    landed += 1
                    break
                except (ChunkServiceError, OSError) as e:
                    errors.append(e)
        if landed == 0:
            # ZERO replicas hold the bytes — the save must not claim this
            # chunk is stored; surface the outage like any unreachable
            # store (the caller's retry/abort policy applies)
            raise errors[-1] if errors else ChunkServiceError(
                f"no reachable replica for {name!r}")
        with self._lock:
            if landed < self.replicas or errors or down:
                self.stats["degraded_puts"] += 1
            self.stats["bytes_uploaded"] += len(blob) * wrote_n
            if referenced:
                # the content already existed somewhere: this save is an
                # incremental reference (any extra copies were repair)
                self.stats["chunks_referenced"] += 1
                self.stats["bytes_referenced"] += raw
            else:
                self.stats["chunks_written"] += 1
                self.stats["bytes_written"] += raw
        return not referenced

    def ref(self, name: str, raw_bytes: int) -> None:
        with self._lock:
            self.stats["chunks_referenced"] += 1
            self.stats["bytes_referenced"] += raw_bytes
        # forward to ONE replica for server-side accounting, best-effort
        for i in self._replica_ids(name):
            if not self._usable(i):
                continue
            try:
                self._try(i, self.shards[i].ref, name, raw_bytes)
                return
            except (ChunkServiceError, OSError):
                continue

    # ------------------------------------------------------------- admin
    def list_chunks(self) -> Set[str]:
        out: Set[str] = set()
        jobs = [(i, sh.list_chunks, ())
                for i, sh in enumerate(self.shards) if self._usable(i)]
        for i, res in self._fanout(jobs):
            if isinstance(res, Exception):
                if not isinstance(res, (OSError, KeyError)):
                    raise res
                continue
            out.update(res)
        return out

    def gc(self, live: Iterable[str]) -> int:
        """Lease-only, like the single-server client: renew this
        client's live-set lease on EVERY shard (each protects its own
        replica copies), remove nothing.  Best-effort per shard."""
        live = set(live)
        for i, sh in enumerate(self.shards):
            if not self._usable(i):
                continue
            try:
                self._try(i, sh.lease, live)
            except (ChunkServiceError, OSError):
                pass
        return 0

    def gc_remote(self, live: Iterable[str]) -> int:
        """Explicit server-side reclamation on every shard.  All shards
        are attempted (even marked-down ones — an admin op should not
        silently skip a shard and leave garbage); the first failure is
        re-raised after the sweep so partial progress still happens."""
        live = sorted(set(live))
        removed = 0
        errors: List[Exception] = []
        for i, res in self._fanout([(i, sh.gc_remote, (live,))
                                    for i, sh in enumerate(self.shards)]):
            if isinstance(res, Exception):
                errors.append(res)
            else:
                removed += res
        with self._lock:
            self.stats["chunks_removed"] += removed
        if errors:
            raise errors[0]
        return removed

    def lease(self, names: Iterable[str], ttl: Optional[float] = None,
              lease_id: Optional[str] = None) -> int:
        """Register/renew the lease on every shard; raises only when NO
        shard accepted it (then nothing protects the chunks)."""
        count: Optional[int] = None
        last: Optional[Exception] = None
        for i, res in self._fanout([(i, sh.lease, (names, ttl, lease_id))
                                    for i, sh in enumerate(self.shards)]):
            if isinstance(res, Exception):
                last = res
            else:
                count = res
        if count is None:
            raise last if last is not None else ChunkServiceError(
                "no shard accepted the lease")
        return count

    def unlease(self, lease_id: Optional[str] = None) -> bool:
        any_dropped = False
        for i, res in self._fanout([(i, sh.unlease, (lease_id,))
                                    for i, sh in enumerate(self.shards)]):
            if not isinstance(res, Exception) and res:
                any_dropped = True
        return any_dropped

    def leases(self) -> dict:
        out: dict = {}
        for i, res in self._fanout([(i, sh.leases, ())
                                    for i, sh in enumerate(self.shards)]):
            if not isinstance(res, Exception):
                out.update(res)
        return out

    def server_stats(self) -> dict:
        """{endpoint: backing-store stats} for every reachable shard."""
        out: dict = {}
        for i, res in self._fanout([(i, sh.server_stats, ())
                                    for i, sh in enumerate(self.shards)]):
            if not isinstance(res, Exception):
                out[self.shards[i].endpoint] = res
        return out

    # ------------------------------------------------------------- health
    def health(self) -> List[dict]:
        """Per-shard health the job surfaces in ``stats()``: endpoint,
        up/down, remaining cooldown, and the shard client's wire
        counters."""
        now = time.monotonic()
        with self._lock:
            down = dict(self._down)
        out = []
        for i, sh in enumerate(self.shards):
            t = down.get(i)
            out.append({
                "endpoint": sh.endpoint,
                "up": t is None,
                "cooldown_s": (0.0 if t is None else
                               max(0.0, tunables.SHARD_RETRY_S
                                   - (now - t))),
                "round_trips": sh.stats["round_trips"],
                "reconnects": sh.stats["reconnects"],
                "bytes_uploaded": sh.stats["bytes_uploaded"],
                "bytes_fetched": sh.stats["bytes_fetched"],
            })
        return out


class CachingChunkStore(ChunkStoreBackend):
    """A local chunk cache layered over a remote backend — a single
    ``RemoteChunkStore`` or a ``ShardedChunkStore`` (the cache is
    placement-blind: it only sees names and bytes).

    SAVE: ``has``/``has_many`` are answered by the SERVER (authoritative
    — another host's restore must be able to fetch every referenced
    chunk), one batched round trip per save; only missing chunks upload
    (``bytes_uploaded``), present ones are referenced
    (``bytes_referenced_remote``, server-side wire bytes).  Every put
    also lands in the cache, so the writing host restores locally.

    RESTORE: ``get`` is cache-first; a miss fetches from the server AND
    pins the blob into the cache (``bytes_fetched``), so the next restore
    of an overlapping manifest moves only what changed — the incremental
    property, now across hosts.  ``prefetch`` pulls a whole working set
    of cache-misses down in batched ``get_many`` calls first — over a
    sharded remote each batch arrives from N servers concurrently.

    GC collects the CACHE only (see module docstring for why); use
    ``gc_remote`` to reclaim the server when the caller owns the
    namespace."""

    wants_batched_has = True

    def __init__(self, cache_root: str | Path,
                 remote: "RemoteChunkStore | ShardedChunkStore"):
        self.cache = ChunkStore(cache_root)
        self.remote = remote
        self.root = self.cache.root
        self._lock = threading.Lock()
        #: {name: server clen} for names the server is KNOWN to hold, and
        #: the set it is known NOT to hold (as of the last query) — both
        #: primed by has_many so the per-chunk puts/refs of a save ride
        #: the ONE batched round trip save_shards already paid.  A stale
        #: negative only costs a redundant idempotent upload; a positive
        #: stays valid as long as this client's live-set lease is renewed
        #: (chunks are immutable and leased chunks are never collected;
        #: gc_remote clears both memos).
        self._known_remote: Dict[str, int] = {}
        self._known_absent: set = set()
        self.stats = {"chunks_written": 0, "chunks_referenced": 0,
                      "bytes_written": 0, "bytes_referenced": 0,
                      "chunks_removed": 0,
                      "bytes_uploaded": 0, "bytes_referenced_remote": 0,
                      "bytes_fetched": 0, "bytes_read": 0,
                      "cache_hits": 0, "cache_misses": 0,
                      "chunks_prefetched": 0}

    @property
    def spec_obj(self) -> StoreSpec:
        return self.remote.spec_obj.with_cache(self.cache.root)

    def close(self) -> None:
        self.remote.close()

    def health(self) -> Optional[List[dict]]:
        """Per-shard health when the remote tier is sharded, else None."""
        fn = getattr(self.remote, "health", None)
        return fn() if fn is not None else None

    # -------------------------------------------------- presence (server)
    def _presence(self, name: str) -> Optional[int]:
        with self._lock:
            if name in self._known_remote:
                return self._known_remote[name]
            if name in self._known_absent:
                return None
        got = self.remote.has_many([name])
        with self._lock:
            self._known_remote.update(got)
            if name not in got:
                self._known_absent.add(name)
        return got.get(name)

    def has(self, name: str) -> bool:
        return self._presence(name) is not None

    def has_many(self, names: Sequence[str]) -> Dict[str, int]:
        with self._lock:
            known = {n: self._known_remote[n] for n in names
                     if n in self._known_remote}
            unknown = [n for n in names
                       if n not in known and n not in self._known_absent]
        if unknown:
            got = self.remote.has_many(unknown)
            with self._lock:
                self._known_remote.update(got)
                self._known_absent.update(n for n in unknown
                                          if n not in got)
            known.update(got)
        return known

    # ----------------------------------------------------- reads (cache)
    def size(self, name: str) -> int:
        if self.cache.has(name):
            return self.cache.size(name)
        clen = self._presence(name)
        if clen is None:
            raise FileNotFoundError(name)
        return clen

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        out: Dict[str, Optional[int]] = {}
        misses = []
        for n in names:
            if self.cache.has(n):
                out[n] = self.cache.size(n)
            else:
                misses.append(n)
        if misses:
            # the VALIDATION view goes to remote.sizes, not has_many: a
            # sharded remote raises there when a name is unresolved and a
            # replica shard was unreachable ("can't tell" must never read
            # as "definitely missing" — gc deletes on the latter)
            got = self.remote.sizes(misses)
            with self._lock:
                self._known_remote.update(
                    {n: sz for n, sz in got.items() if sz is not None})
            out.update(got)
        return {n: out.get(n) for n in names}

    def prefetch(self, names: Sequence[str]) -> int:
        """Pin every cache-missing name in `names` into the cache via
        batched ``get_many`` round trips (``REPRO_CHUNK_PREFETCH_BATCH``
        names each — bounds any one reply buffer); over a sharded remote
        each batch fans out per shard, so the restore working set rides N
        sockets at once.  Returns the wire bytes fetched.  Names the
        remote doesn't hold are left for the per-chunk ``get`` ladder."""
        miss = [n for n in names if not self.cache.has(n)]
        fetched = 0
        step = max(1, int(tunables.CHUNK_PREFETCH_BATCH))
        for k in range(0, len(miss), step):
            got = self.remote.get_many(miss[k:k + step])
            for n, blob in got.items():
                self.cache.put(n, blob)
                fetched += len(blob)
                with self._lock:
                    self._known_remote.setdefault(n, len(blob))
                    self.stats["chunks_prefetched"] += 1
        if fetched:
            with self._lock:
                self.stats["bytes_fetched"] += fetched
        return fetched

    def get(self, name: str) -> bytes:
        if self.cache.has(name):
            blob = self.cache.get(name)
            with self._lock:
                self.stats["cache_hits"] += 1
                self.stats["bytes_read"] += len(blob)
            return blob
        blob = self.remote.get(name)
        self.cache.put(name, blob)          # pin: next restore is local
        with self._lock:
            self._known_remote.setdefault(name, len(blob))
            self.stats["cache_misses"] += 1
            self.stats["bytes_fetched"] += len(blob)
            self.stats["bytes_read"] += len(blob)
        return blob

    # ---------------------------------------------------- writes (server)
    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        raw = raw_bytes or len(blob)
        if not self.cache.has(name):
            self.cache.put(name, blob, raw_bytes=raw)
        clen = self._presence(name)
        if clen is not None:
            with self._lock:
                self.stats["chunks_referenced"] += 1
                self.stats["bytes_referenced"] += raw
                self.stats["bytes_referenced_remote"] += clen
            return False
        self.remote.put(name, blob, raw_bytes=raw)
        with self._lock:
            self._known_remote[name] = len(blob)
            self._known_absent.discard(name)
            self.stats["chunks_written"] += 1
            self.stats["bytes_written"] += raw
            self.stats["bytes_uploaded"] += len(blob)
        return True

    def ref(self, name: str, raw_bytes: int) -> None:
        # counters only — no wire: a 13-of-16 incremental save must not
        # pay 13 round trips to bump a server-side stat (pure
        # RemoteChunkStore clients still forward REF; server stats then
        # describe their traffic)
        clen = self._presence(name)
        with self._lock:
            self.stats["chunks_referenced"] += 1
            self.stats["bytes_referenced"] += raw_bytes
            self.stats["bytes_referenced_remote"] += clen or 0

    # -------------------------------------------------------------- admin
    def list_chunks(self) -> Set[str]:
        return self.cache.list_chunks() | self.remote.list_chunks()

    def gc(self, live: Iterable[str]) -> int:
        """Collect the CACHE only, and renew this client's server-side
        lease over `live` (best-effort — see RemoteChunkStore.gc)."""
        live = set(live)
        removed = self.cache.gc(live)
        try:
            self.remote.lease(live)
        except (ChunkServiceError, OSError):
            pass
        with self._lock:
            self.stats["chunks_removed"] += removed
        return removed

    def gc_remote(self, live: Iterable[str]) -> int:
        removed = self.remote.gc_remote(live)
        with self._lock:
            self._known_remote = {}
            self._known_absent = set()
        return removed

    def lease(self, names: Iterable[str], ttl: Optional[float] = None,
              lease_id: Optional[str] = None) -> int:
        return self.remote.lease(names, ttl, lease_id)

    def unlease(self, lease_id: Optional[str] = None) -> bool:
        return self.remote.unlease(lease_id)


# =========================================================================
# CLI: serve one shard
# =========================================================================

def _main(argv=None):
    """``python -m repro.checkpoint.chunkservice DIR [--port P]`` — serve
    one chunk directory over a socket.  Run N of these and list every
    ``host:port`` in one StoreSpec to form a shard set (DESIGN.md §15)."""
    import argparse
    ap = argparse.ArgumentParser(
        description="Serve a content-addressed chunk directory over a "
                    "socket — one shard of a remote:// endpoint list.")
    ap.add_argument("root", help="backing directory for this shard")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address (default 127.0.0.1)")
    ap.add_argument("--port", type=int, default=0,
                    help="port to listen on (0 picks a free one)")
    ap.add_argument("--advertise-host", default=None,
                    help="dialable name to print when binding a wildcard")
    ap.add_argument("--auto-gc-interval", type=float, default=None,
                    help="server-side lease-aware gc sweep period, seconds")
    args = ap.parse_args(argv)
    srv = ChunkServer(args.root, host=args.host, port=args.port,
                      advertise_host=args.advertise_host,
                      auto_gc_interval=args.auto_gc_interval).start()
    print(f"chunkserver: {args.root} on {srv.host}:{srv.port}", flush=True)
    try:
        threading.Event().wait()
    except KeyboardInterrupt:
        pass
    finally:
        srv.stop()


if __name__ == "__main__":
    _main()
