"""Content-addressed chunk store — the storage half of incremental
checkpoints (DESIGN.md §9).

A chunk is an immutable file named by the digest of its UNCOMPRESSED
content: ``<store root>/<digest>.<ext>`` (the extension records the codec).
Checkpoint manifests reference chunks by name, so two checkpoints whose
leaves did not change between saves share the same chunk files on disk and
the second save writes nothing for them.  Deletion is refcounting over
live manifests: a chunk is removed only when no remaining manifest
references it (``gc``).

Because the name IS the content digest, chunks are self-validating: a deep
check re-derives the digest from the (decompressed) bytes and compares it
to the filename — no separate crc bookkeeping can drift out of sync.

Writes are atomic (tmp file + rename) and idempotent: two writers racing
on the same digest produce byte-identical content, so whichever rename
lands last is indistinguishable from the first.

Since PR 5 the store is PLUGGABLE (DESIGN.md §11): every consumer writes
against the ``ChunkStoreBackend`` interface below, and ``open_store``
resolves a *spec* to a backend, so a checkpoint can live behind a socket
exactly like the MPI fabric does.

Since PR 9 the spec itself is STRUCTURED (DESIGN.md §15): ``StoreSpec``
is the one description of "where chunks live" — scheme, endpoints,
namespace, replication, cache directory — with a canonical string form
that round-trips through ``StoreSpec.parse``:

    /path/to/chunks                                   (local directory)
    remote://host:port[/ns][?cache=DIR]               (one chunk server)
    remote://h1:p1,h2:p2,h3:p3[/ns][?cache=DIR&replicas=2]   (sharded)

Every consumer — ``open_store``, manifests, the process world's
``ckpt_info`` hand-off, migration destinations — speaks this ONE grammar;
a sharded deployment composes (more endpoints, a replicas knob) instead
of growing another string dialect.  ``open_store`` accepts old-style
strings, ``Path``s, ``StoreSpec`` objects, or an already-built backend,
and every backend's ``spec`` property returns the canonical string.
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import re
import threading
import urllib.parse
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, Optional, Sequence, Set, Tuple


def content_digest(buf) -> str:
    """Digest of a bytes-like/buffer (memoryviews welcome — no copy)."""
    return hashlib.blake2b(buf, digest_size=16).hexdigest()


#: chunk names, namespaces and lease ids are digest-shaped tokens;
#: anything else is rejected (a name is used as a path component).
#: Shared with the chunk service, which enforces it server-side.
SAFE_TOKEN = re.compile(r"^[A-Za-z0-9._-]+$")


def check_token(tok: str, what: str) -> str:
    # fullmatch (a trailing newline must not slip past a $-anchor) and no
    # dot-only tokens: namespace "." would alias a server's default
    # namespace and break cross-job isolation
    if (not SAFE_TOKEN.fullmatch(tok) or ".." in tok
            or set(tok) == {"."}):
        raise ValueError(f"illegal {what} {tok!r}")
    return tok


_ENDPOINT = re.compile(r"^[A-Za-z0-9._\-\[\]]+:\d+$")


@dataclass(frozen=True)
class StoreSpec:
    """Structured description of a chunk store (DESIGN.md §15).

    One object replaces the ad-hoc strings that used to thread through
    ``open_store``/``spec()``:

      * ``scheme``     — ``"local"`` (a directory) or ``"remote"`` (one
        or more chunk servers);
      * ``endpoints``  — ``("host:port", ...)`` for remote stores; more
        than one endpoint means a digest-space-sharded store and the
        ORDER is the shard map (two specs with permuted endpoints are
        different stores);
      * ``path``       — the root directory for local stores;
      * ``namespace``  — server-side isolation unit (empty = default);
      * ``replicas``   — how many endpoints each chunk is written to;
        ``None`` means "the store default" (``REPRO_REPLICAS``, clamped
        to ``len(endpoints)`` at open time), an explicit int is obeyed
        (also clamped) and survives the round trip;
      * ``cache``      — local cache directory layered over a remote
        (``CachingChunkStore``).

    ``canonical()`` and ``parse()`` round-trip exactly; the canonical
    string is what manifests record and what process-world children are
    handed, so it must stay stable across processes and hosts."""

    scheme: str = "local"
    endpoints: Tuple[str, ...] = ()
    path: Optional[str] = None
    namespace: str = ""
    replicas: Optional[int] = None
    cache: Optional[str] = None

    def __post_init__(self):
        # normalize Path-typed fields so equality/round-trip are exact
        if self.path is not None and not isinstance(self.path, str):
            object.__setattr__(self, "path", str(self.path))
        if self.cache is not None and not isinstance(self.cache, str):
            object.__setattr__(self, "cache", str(self.cache))
        if not isinstance(self.endpoints, tuple):
            object.__setattr__(self, "endpoints", tuple(self.endpoints))
        if self.scheme == "local":
            if not self.path:
                raise ValueError("local StoreSpec needs a path")
            if self.endpoints or self.cache or self.replicas is not None:
                raise ValueError(
                    "local StoreSpec takes no endpoints/cache/replicas")
        elif self.scheme == "remote":
            if not self.endpoints:
                raise ValueError("remote StoreSpec needs endpoints")
            for ep in self.endpoints:
                if not _ENDPOINT.fullmatch(ep):
                    raise ValueError(f"endpoint needs host:port, got {ep!r}")
            if len(set(self.endpoints)) != len(self.endpoints):
                raise ValueError(
                    f"duplicate endpoints in {self.endpoints!r}")
            if self.replicas is not None and self.replicas < 1:
                raise ValueError(f"replicas must be >= 1, "
                                 f"got {self.replicas}")
        else:
            raise ValueError(f"unknown store scheme {self.scheme!r}")
        if self.namespace:
            check_token(self.namespace, "namespace")

    # ------------------------------------------------------------- parse
    @classmethod
    def parse(cls, spec) -> "StoreSpec":
        """Resolve any accepted spec shape — a ``StoreSpec`` (returned
        as-is), a ``remote://`` string (old single-endpoint strings
        included), or a local path string/Path."""
        if isinstance(spec, cls):
            return spec
        text = str(spec)
        if not text.startswith("remote://"):
            return cls(scheme="local", path=text)
        rest = text[len("remote://"):]
        cache: Optional[str] = None
        replicas: Optional[int] = None
        if "?" in rest:
            rest, query = rest.split("?", 1)
            for kv in query.split("&"):
                k, _, v = kv.partition("=")
                if k == "cache" and v:
                    # percent-decoded: cache dirs are user paths and may
                    # legally contain ``?``/``&`` (canonical() quotes)
                    cache = urllib.parse.unquote(v)
                elif k == "replicas" and v.isdigit():
                    replicas = int(v)
                else:
                    raise ValueError(
                        f"unknown spec parameter {kv!r} in {text!r}")
        ns = ""
        if "/" in rest:
            rest, ns = rest.split("/", 1)
        endpoints = tuple(e for e in rest.split(",") if e)
        if not endpoints:
            raise ValueError(f"spec needs host:port, got {text!r}")
        return cls(scheme="remote", endpoints=endpoints, namespace=ns,
                   replicas=replicas, cache=cache)

    # --------------------------------------------------------- canonical
    def canonical(self) -> str:
        """The one string form of this spec; ``parse(canonical())`` is
        the identity.  Local specs stay plain paths (manifests written
        before StoreSpec remain byte-identical); remote specs list
        endpoints in shard order with query keys in canonical
        (alphabetical) order."""
        if self.scheme == "local":
            return self.path
        out = "remote://" + ",".join(self.endpoints)
        if self.namespace:
            out += f"/{self.namespace}"
        params = []
        if self.cache:
            params.append(
                f"cache={urllib.parse.quote(self.cache, safe='/')}")
        if self.replicas is not None:
            params.append(f"replicas={self.replicas}")
        if params:
            out += "?" + "&".join(params)
        return out

    def __str__(self) -> str:
        return self.canonical()

    # ------------------------------------------------------- composition
    def with_cache(self, cache: Optional[str | Path]) -> "StoreSpec":
        """The same store seen through a local cache directory (the
        migration destination / fresh-host shape)."""
        return dataclasses.replace(
            self, cache=str(cache) if cache is not None else None)

    def without_cache(self) -> "StoreSpec":
        """The portable form third-party readers use for fetch-on-miss —
        what manifests record (another host must not try to create/pin
        into the writer's cache path)."""
        return dataclasses.replace(self, cache=None)

    def with_namespace(self, namespace: str) -> "StoreSpec":
        return dataclasses.replace(self, namespace=namespace)

    def with_replicas(self, replicas: Optional[int]) -> "StoreSpec":
        return dataclasses.replace(self, replicas=replicas)

    @property
    def sharded(self) -> bool:
        return len(self.endpoints) > 1


def _fresh_stats() -> Dict[str, int]:
    return {"chunks_written": 0, "chunks_referenced": 0,
            "bytes_written": 0, "bytes_referenced": 0,
            "chunks_removed": 0}


class ChunkStoreBackend:
    """The storage interface both checkpoint layers write against.

    Implementations: ``ChunkStore`` (one local directory — below),
    ``RemoteChunkStore`` (a socket client to a ``ChunkServer``) and
    ``CachingChunkStore`` (local cache over a remote, fetch-on-miss) in
    checkpoint/chunkservice.py.  All must be thread-safe: ``put`` runs
    concurrently from writer-pool threads.

    ``stats`` carries at least the counters in ``_fresh_stats`` —
    ``bytes_written``/``bytes_referenced`` are in RAW (uncompressed)
    bytes, the currency of ``delta_write_fraction``; networked backends
    add wire-byte counters (``bytes_uploaded`` etc.) on top.
    """

    #: save pipelines group shard digests into ONE has_many round trip
    #: before compressing/uploading when this is True (networked stores);
    #: a local store answers has() with a stat call and skips the barrier
    wants_batched_has = False

    #: local directory the chunks land in, when there is one (used for the
    #: manifest's relative ``chunk_dir``); None for a pure remote store
    root: Optional[Path] = None

    @property
    def spec_obj(self) -> StoreSpec:
        """Structured description of this store; ``spec``/``fetch_spec``
        are derived canonical strings."""
        raise NotImplementedError

    @property
    def spec(self) -> str:
        """Round-trippable canonical description of this store:
        ``open_store(spec)`` in ANOTHER PROCESS builds an equivalent
        backend (the process world hands it to rank children)."""
        return self.spec_obj.canonical()

    @property
    def fetch_spec(self) -> str:
        """The spec a THIRD-PARTY reader should use for fetch-on-miss —
        what manifests record.  For a caching store this strips the
        writer-host-local cache directory (another host must not try to
        create/pin into the writer's path); defaults to ``spec``."""
        return self.spec_obj.without_cache().canonical()

    def has(self, name: str) -> bool:
        raise NotImplementedError

    def size(self, name: str) -> int:
        raise NotImplementedError

    def get(self, name: str) -> bytes:
        raise NotImplementedError

    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        raise NotImplementedError

    def ref(self, name: str, raw_bytes: int) -> None:
        raise NotImplementedError

    def list_chunks(self) -> Set[str]:
        raise NotImplementedError

    def gc(self, live: Iterable[str]) -> int:
        raise NotImplementedError

    # ---- batched queries (backends override with one-round-trip versions)
    def has_many(self, names: Sequence[str]) -> Dict[str, int]:
        """{name: stored size} for every name PRESENT — the upload
        decision ("do I need to ship these bytes?")."""
        out: Dict[str, int] = {}
        for n in names:
            if self.has(n):
                out[n] = self.size(n)
        return out

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        """{name: readable size or None} — the validation view ("can a
        restore through THIS store read the chunk?"); for a caching store
        this consults the cache first, then the remote."""
        return {n: (self.size(n) if self.has(n) else None) for n in names}

    def close(self) -> None:
        """Release any connection this backend holds (no-op for local)."""


def open_store(spec, default=None) -> "ChunkStoreBackend":
    """THE resolution point from a spec to a backend — every
    ``ckpt_store=`` parameter in the system (``MPIJob``, ``restart``,
    ``CheckpointManager``, ``migrate`` destinations, process-world
    children) funnels through here:

      * an existing ``ChunkStoreBackend`` passes through untouched;
      * a ``StoreSpec`` (or any string ``StoreSpec.parse`` accepts —
        old ``remote://host:port[/ns][?cache=DIR]`` strings included)
        builds the matching backend: ``RemoteChunkStore`` for one
        endpoint, ``ShardedChunkStore`` for several, wrapped in a
        ``CachingChunkStore`` when the spec carries a cache dir;
      * anything else is a local directory -> ``ChunkStore``.

    ``default`` is used when `spec` is None.  The CI remote-store leg
    wraps THIS function (tests/conftest.py) to reroute local specs
    through a shared ChunkServer — call it through the module
    (``chunkstore.open_store``) so the override is seen.
    """
    if spec is None:
        spec = default
    if spec is None:
        raise ValueError("no chunk store spec and no default")
    if isinstance(spec, ChunkStoreBackend):
        return spec
    sp = StoreSpec.parse(spec)
    if sp.scheme == "remote":
        from repro.checkpoint.chunkservice import store_from_spec
        return store_from_spec(sp)
    return ChunkStore(sp.path)


class ChunkReader:
    """Chunk access for ONE checkpoint manifest, in preference order:

      1. an explicit ``store`` backend (a CheckpointManager's, or the
         ``ckpt_store`` handed to an elastic restart) — covers
         cache-then-fetch for caching backends;
      2. the manifest's local ``chunk_dir`` (fast path: plain file io) —
         ALSO consulted when the explicit store misses, so a
         self-contained checkpoint written before a shared store was
         adopted stays restorable;
      3. on a miss everywhere else, a backend opened lazily from the
         manifest's recorded ``store`` spec (fetch-on-miss: a reader on a
         host that never saw this checkpoint pulls exactly the chunks it
         lacks).

    Works for BOTH manifest layers (tensor leaves and rank images) —
    each records the same ``chunk_dir`` / ``store`` keys.
    """

    def __init__(self, ckpt_dir, man: dict,
                 store: Optional[ChunkStoreBackend] = None):
        self.dir = Path(ckpt_dir)
        self.chunk_dir = man.get("chunk_dir", "chunks")
        self.store = store
        self._spec = man.get("store")
        self._fallback: Optional[ChunkStoreBackend] = None

    def _spec_store(self) -> Optional[ChunkStoreBackend]:
        if self._fallback is None and self._spec:
            self._fallback = open_store(self._spec)
        return self._fallback

    def path(self, name: str) -> Path:
        return self.dir / self.chunk_dir / name

    def get(self, name: str) -> bytes:
        unreachable: Optional[ConnectionError] = None
        if self.store is not None:
            try:
                return self.store.get(name)
            except ConnectionError as e:
                unreachable = e    # try local before giving up
            except (OSError, KeyError):
                pass       # fall through to the checkpoint's own chunks
        try:
            return self.path(name).read_bytes()
        except FileNotFoundError:
            if unreachable is not None:
                # absent locally AND the store couldn't be asked: report
                # the outage, not a phantom "chunk does not exist"
                raise unreachable
            fb = self._spec_store()
            if fb is None:
                raise
            return fb.get(name)

    def prefetch(self, names: Sequence[str]) -> int:
        """Pull the restore working set down in bulk BEFORE the per-chunk
        ``get`` calls: names that are neither locally present nor already
        cached are fetched through the backend's batched ``get_many``
        fan-out (one round trip per shard for a sharded store) and pinned
        into its cache.  Returns the wire bytes fetched; 0 when the
        backend has no ``prefetch`` (local stores) or is unreachable —
        the per-chunk ladder in ``get`` remains the authority, so a
        failed prefetch degrades to the old path instead of failing the
        restore."""
        store = self.store
        fn = getattr(store, "prefetch", None)
        if fn is None and self._spec:
            store = self._spec_store()
            fn = getattr(store, "prefetch", None)
        if fn is None:
            return 0
        miss = [n for n in names if not self.path(n).is_file()]
        if not miss:
            return 0
        try:
            return fn(miss)
        except ConnectionError:
            return 0

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        """{name: readable size or None}; one batched query against the
        backend, the local directory covering whatever it misses (and
        vice versa), the manifest's spec store last.  Raises
        ConnectionError when a name is locally absent AND the backend
        that should know about it is unreachable — "can't tell" must
        never read as "definitely missing" (gc deletes on the latter)."""
        out: Dict[str, Optional[int]] = {}
        unreachable: Optional[ConnectionError] = None
        if self.store is not None:
            try:
                out = dict(self.store.sizes(names))
            except ConnectionError as e:
                unreachable = e
        misses = []
        for n in names:
            if out.get(n) is not None:
                continue
            try:
                out[n] = self.path(n).stat().st_size
            except OSError:
                misses.append(n)
        if misses:
            fb = self._spec_store()     # last resort, like get()
            if fb is not None:
                out.update(fb.sizes(misses))
                misses = [n for n in misses if out.get(n) is None]
        if misses and unreachable is not None:
            raise unreachable
        return {n: out.get(n) for n in names}


class ChunkStore(ChunkStoreBackend):
    """One flat directory of content-addressed chunk files.

    Thread-safe: ``put`` may be called concurrently from writer-pool
    threads (and from several rank threads sharing one store); stats
    updates are lock-protected, file writes are atomic renames.
    """

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self._lock = threading.Lock()
        self.stats = _fresh_stats()

    @property
    def spec_obj(self) -> StoreSpec:
        return StoreSpec(scheme="local", path=str(self.root))

    # ------------------------------------------------------------------ io
    def path(self, name: str) -> Path:
        return self.root / name

    def has(self, name: str) -> bool:
        return (self.root / name).is_file()

    def size(self, name: str) -> int:
        return (self.root / name).stat().st_size

    def ref(self, name: str, raw_bytes: int) -> None:
        """Count an incremental reference: the chunk already exists and this
        save points at it instead of rewriting it."""
        with self._lock:
            self.stats["chunks_referenced"] += 1
            self.stats["bytes_referenced"] += raw_bytes

    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        """Store `blob` under `name` unless present.  Returns True when this
        call wrote the chunk, False when it was already stored (a reference,
        the incremental fast path).  `raw_bytes` is the uncompressed payload
        size, credited to the written/referenced byte counters."""
        p = self.root / name
        if p.is_file():
            self.ref(name, raw_bytes or len(blob))
            return False
        self.root.mkdir(parents=True, exist_ok=True)
        # tmp name must be unique per WRITER, and writers can now live in
        # different processes (process-world rank children share one store):
        # thread idents alone collide across forked children — same main
        # thread address — so qualify with the pid too
        tmp = p.with_name(
            p.name + f".tmp{os.getpid()}-{threading.get_ident()}")
        tmp.write_bytes(blob)
        os.replace(tmp, p)
        with self._lock:
            self.stats["chunks_written"] += 1
            self.stats["bytes_written"] += raw_bytes or len(blob)
        return True

    def get(self, name: str) -> bytes:
        return (self.root / name).read_bytes()

    # ------------------------------------------------------------------ gc
    def list_chunks(self) -> Set[str]:
        if not self.root.is_dir():
            return set()
        return {p.name for p in self.root.iterdir()
                if p.is_file() and ".tmp" not in p.name}

    def gc(self, live: Iterable[str]) -> int:
        """Remove every chunk NOT in `live` (the union of chunk names
        referenced by all manifests the caller intends to keep).  Returns
        the number removed.  Stale tmp files are always collected."""
        live = set(live)
        removed = 0
        if not self.root.is_dir():
            return 0
        for p in list(self.root.iterdir()):
            if not p.is_file():
                continue
            if ".tmp" in p.name or p.name not in live:
                try:
                    p.unlink()
                    removed += 1
                except OSError:
                    pass
        with self._lock:
            self.stats["chunks_removed"] += removed
        return removed
