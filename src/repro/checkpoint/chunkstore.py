"""Content-addressed chunk store — the storage half of incremental
checkpoints (DESIGN.md §9).

A chunk is an immutable file named by the digest of its UNCOMPRESSED
content: ``<store root>/<digest>.<ext>`` (the extension records the codec).
Checkpoint manifests reference chunks by name, so two checkpoints whose
leaves did not change between saves share the same chunk files on disk and
the second save writes nothing for them.  Deletion is refcounting over
live manifests: a chunk is removed only when no remaining manifest
references it (``gc``).

Because the name IS the content digest, chunks are self-validating: a deep
check re-derives the digest from the (decompressed) bytes and compares it
to the filename — no separate crc bookkeeping can drift out of sync.

Writes are atomic (tmp file + rename) and idempotent: two writers racing
on the same digest produce byte-identical content, so whichever rename
lands last is indistinguishable from the first.

Since PR 5 the store is PLUGGABLE (DESIGN.md §11): every consumer writes
against the ``ChunkStoreBackend`` interface below, and ``open_store``
resolves a *spec* — a directory path, a ``remote://host:port[/ns]``
address (checkpoint/chunkservice.py), or an already-built backend — so a
checkpoint can live behind a socket exactly like the MPI fabric does.
"""
from __future__ import annotations

import hashlib
import os
import threading
from pathlib import Path
from typing import Dict, Iterable, Optional, Sequence, Set


def content_digest(buf) -> str:
    """Digest of a bytes-like/buffer (memoryviews welcome — no copy)."""
    return hashlib.blake2b(buf, digest_size=16).hexdigest()


def _fresh_stats() -> Dict[str, int]:
    return {"chunks_written": 0, "chunks_referenced": 0,
            "bytes_written": 0, "bytes_referenced": 0,
            "chunks_removed": 0}


class ChunkStoreBackend:
    """The storage interface both checkpoint layers write against.

    Implementations: ``ChunkStore`` (one local directory — below),
    ``RemoteChunkStore`` (a socket client to a ``ChunkServer``) and
    ``CachingChunkStore`` (local cache over a remote, fetch-on-miss) in
    checkpoint/chunkservice.py.  All must be thread-safe: ``put`` runs
    concurrently from writer-pool threads.

    ``stats`` carries at least the counters in ``_fresh_stats`` —
    ``bytes_written``/``bytes_referenced`` are in RAW (uncompressed)
    bytes, the currency of ``delta_write_fraction``; networked backends
    add wire-byte counters (``bytes_uploaded`` etc.) on top.
    """

    #: save pipelines group shard digests into ONE has_many round trip
    #: before compressing/uploading when this is True (networked stores);
    #: a local store answers has() with a stat call and skips the barrier
    wants_batched_has = False

    #: local directory the chunks land in, when there is one (used for the
    #: manifest's relative ``chunk_dir``); None for a pure remote store
    root: Optional[Path] = None

    @property
    def spec(self) -> str:
        """Round-trippable description of this store: ``open_store(spec)``
        in ANOTHER PROCESS builds an equivalent backend (the process world
        hands it to rank children)."""
        raise NotImplementedError

    @property
    def fetch_spec(self) -> str:
        """The spec a THIRD-PARTY reader should use for fetch-on-miss —
        what manifests record.  For a caching store this strips the
        writer-host-local cache directory (another host must not try to
        create/pin into the writer's path); defaults to ``spec``."""
        return self.spec

    def has(self, name: str) -> bool:
        raise NotImplementedError

    def size(self, name: str) -> int:
        raise NotImplementedError

    def get(self, name: str) -> bytes:
        raise NotImplementedError

    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        raise NotImplementedError

    def ref(self, name: str, raw_bytes: int) -> None:
        raise NotImplementedError

    def list_chunks(self) -> Set[str]:
        raise NotImplementedError

    def gc(self, live: Iterable[str]) -> int:
        raise NotImplementedError

    # ---- batched queries (backends override with one-round-trip versions)
    def has_many(self, names: Sequence[str]) -> Dict[str, int]:
        """{name: stored size} for every name PRESENT — the upload
        decision ("do I need to ship these bytes?")."""
        out: Dict[str, int] = {}
        for n in names:
            if self.has(n):
                out[n] = self.size(n)
        return out

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        """{name: readable size or None} — the validation view ("can a
        restore through THIS store read the chunk?"); for a caching store
        this consults the cache first, then the remote."""
        return {n: (self.size(n) if self.has(n) else None) for n in names}

    def close(self) -> None:
        """Release any connection this backend holds (no-op for local)."""


def open_store(spec, default=None) -> "ChunkStoreBackend":
    """Resolve a store spec to a backend:

      * an existing ``ChunkStoreBackend`` passes through untouched;
      * ``"remote://host:port[/ns][?cache=DIR]"`` builds a
        ``RemoteChunkStore`` (or ``CachingChunkStore`` with ``cache=``);
      * anything else is a local directory -> ``ChunkStore``.

    ``default`` is used when `spec` is None.  The CI remote-store leg
    wraps THIS function (tests/conftest.py) to reroute local specs
    through a shared ChunkServer — call it through the module
    (``chunkstore.open_store``) so the override is seen.
    """
    if spec is None:
        spec = default
    if spec is None:
        raise ValueError("no chunk store spec and no default")
    if isinstance(spec, ChunkStoreBackend):
        return spec
    if isinstance(spec, str) and spec.startswith("remote://"):
        from repro.checkpoint.chunkservice import store_from_spec
        return store_from_spec(spec)
    return ChunkStore(spec)


class ChunkReader:
    """Chunk access for ONE checkpoint manifest, in preference order:

      1. an explicit ``store`` backend (a CheckpointManager's, or the
         ``ckpt_store`` handed to an elastic restart) — covers
         cache-then-fetch for caching backends;
      2. the manifest's local ``chunk_dir`` (fast path: plain file io) —
         ALSO consulted when the explicit store misses, so a
         self-contained checkpoint written before a shared store was
         adopted stays restorable;
      3. on a miss everywhere else, a backend opened lazily from the
         manifest's recorded ``store`` spec (fetch-on-miss: a reader on a
         host that never saw this checkpoint pulls exactly the chunks it
         lacks).

    Works for BOTH manifest layers (tensor leaves and rank images) —
    each records the same ``chunk_dir`` / ``store`` keys.
    """

    def __init__(self, ckpt_dir, man: dict,
                 store: Optional[ChunkStoreBackend] = None):
        self.dir = Path(ckpt_dir)
        self.chunk_dir = man.get("chunk_dir", "chunks")
        self.store = store
        self._spec = man.get("store")
        self._fallback: Optional[ChunkStoreBackend] = None

    def _spec_store(self) -> Optional[ChunkStoreBackend]:
        if self._fallback is None and self._spec:
            self._fallback = open_store(self._spec)
        return self._fallback

    def path(self, name: str) -> Path:
        return self.dir / self.chunk_dir / name

    def get(self, name: str) -> bytes:
        unreachable: Optional[ConnectionError] = None
        if self.store is not None:
            try:
                return self.store.get(name)
            except ConnectionError as e:
                unreachable = e    # try local before giving up
            except (OSError, KeyError):
                pass       # fall through to the checkpoint's own chunks
        try:
            return self.path(name).read_bytes()
        except FileNotFoundError:
            if unreachable is not None:
                # absent locally AND the store couldn't be asked: report
                # the outage, not a phantom "chunk does not exist"
                raise unreachable
            fb = self._spec_store()
            if fb is None:
                raise
            return fb.get(name)

    def sizes(self, names: Sequence[str]) -> Dict[str, Optional[int]]:
        """{name: readable size or None}; one batched query against the
        backend, the local directory covering whatever it misses (and
        vice versa), the manifest's spec store last.  Raises
        ConnectionError when a name is locally absent AND the backend
        that should know about it is unreachable — "can't tell" must
        never read as "definitely missing" (gc deletes on the latter)."""
        out: Dict[str, Optional[int]] = {}
        unreachable: Optional[ConnectionError] = None
        if self.store is not None:
            try:
                out = dict(self.store.sizes(names))
            except ConnectionError as e:
                unreachable = e
        misses = []
        for n in names:
            if out.get(n) is not None:
                continue
            try:
                out[n] = self.path(n).stat().st_size
            except OSError:
                misses.append(n)
        if misses:
            fb = self._spec_store()     # last resort, like get()
            if fb is not None:
                out.update(fb.sizes(misses))
                misses = [n for n in misses if out.get(n) is None]
        if misses and unreachable is not None:
            raise unreachable
        return {n: out.get(n) for n in names}


class ChunkStore(ChunkStoreBackend):
    """One flat directory of content-addressed chunk files.

    Thread-safe: ``put`` may be called concurrently from writer-pool
    threads (and from several rank threads sharing one store); stats
    updates are lock-protected, file writes are atomic renames.
    """

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self._lock = threading.Lock()
        self.stats = _fresh_stats()

    @property
    def spec(self) -> str:
        return str(self.root)

    # ------------------------------------------------------------------ io
    def path(self, name: str) -> Path:
        return self.root / name

    def has(self, name: str) -> bool:
        return (self.root / name).is_file()

    def size(self, name: str) -> int:
        return (self.root / name).stat().st_size

    def ref(self, name: str, raw_bytes: int) -> None:
        """Count an incremental reference: the chunk already exists and this
        save points at it instead of rewriting it."""
        with self._lock:
            self.stats["chunks_referenced"] += 1
            self.stats["bytes_referenced"] += raw_bytes

    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        """Store `blob` under `name` unless present.  Returns True when this
        call wrote the chunk, False when it was already stored (a reference,
        the incremental fast path).  `raw_bytes` is the uncompressed payload
        size, credited to the written/referenced byte counters."""
        p = self.root / name
        if p.is_file():
            self.ref(name, raw_bytes or len(blob))
            return False
        self.root.mkdir(parents=True, exist_ok=True)
        # tmp name must be unique per WRITER, and writers can now live in
        # different processes (process-world rank children share one store):
        # thread idents alone collide across forked children — same main
        # thread address — so qualify with the pid too
        tmp = p.with_name(
            p.name + f".tmp{os.getpid()}-{threading.get_ident()}")
        tmp.write_bytes(blob)
        os.replace(tmp, p)
        with self._lock:
            self.stats["chunks_written"] += 1
            self.stats["bytes_written"] += raw_bytes or len(blob)
        return True

    def get(self, name: str) -> bytes:
        return (self.root / name).read_bytes()

    # ------------------------------------------------------------------ gc
    def list_chunks(self) -> Set[str]:
        if not self.root.is_dir():
            return set()
        return {p.name for p in self.root.iterdir()
                if p.is_file() and ".tmp" not in p.name}

    def gc(self, live: Iterable[str]) -> int:
        """Remove every chunk NOT in `live` (the union of chunk names
        referenced by all manifests the caller intends to keep).  Returns
        the number removed.  Stale tmp files are always collected."""
        live = set(live)
        removed = 0
        if not self.root.is_dir():
            return 0
        for p in list(self.root.iterdir()):
            if not p.is_file():
                continue
            if ".tmp" in p.name or p.name not in live:
                try:
                    p.unlink()
                    removed += 1
                except OSError:
                    pass
        with self._lock:
            self.stats["chunks_removed"] += removed
        return removed
