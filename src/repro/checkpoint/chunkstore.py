"""Content-addressed chunk store — the storage half of incremental
checkpoints (DESIGN.md §9).

A chunk is an immutable file named by the digest of its UNCOMPRESSED
content: ``<store root>/<digest>.<ext>`` (the extension records the codec).
Checkpoint manifests reference chunks by name, so two checkpoints whose
leaves did not change between saves share the same chunk files on disk and
the second save writes nothing for them.  Deletion is refcounting over
live manifests: a chunk is removed only when no remaining manifest
references it (``gc``).

Because the name IS the content digest, chunks are self-validating: a deep
check re-derives the digest from the (decompressed) bytes and compares it
to the filename — no separate crc bookkeeping can drift out of sync.

Writes are atomic (tmp file + rename) and idempotent: two writers racing
on the same digest produce byte-identical content, so whichever rename
lands last is indistinguishable from the first.
"""
from __future__ import annotations

import hashlib
import os
import threading
from pathlib import Path
from typing import Iterable, Set


def content_digest(buf) -> str:
    """Digest of a bytes-like/buffer (memoryviews welcome — no copy)."""
    return hashlib.blake2b(buf, digest_size=16).hexdigest()


class ChunkStore:
    """One flat directory of content-addressed chunk files.

    Thread-safe: ``put`` may be called concurrently from writer-pool
    threads (and from several rank threads sharing one store); stats
    updates are lock-protected, file writes are atomic renames.
    """

    def __init__(self, root: str | Path):
        self.root = Path(root)
        self._lock = threading.Lock()
        self.stats = {"chunks_written": 0, "chunks_referenced": 0,
                      "bytes_written": 0, "bytes_referenced": 0,
                      "chunks_removed": 0}

    # ------------------------------------------------------------------ io
    def path(self, name: str) -> Path:
        return self.root / name

    def has(self, name: str) -> bool:
        return (self.root / name).is_file()

    def size(self, name: str) -> int:
        return (self.root / name).stat().st_size

    def ref(self, name: str, raw_bytes: int) -> None:
        """Count an incremental reference: the chunk already exists and this
        save points at it instead of rewriting it."""
        with self._lock:
            self.stats["chunks_referenced"] += 1
            self.stats["bytes_referenced"] += raw_bytes

    def put(self, name: str, blob: bytes, raw_bytes: int = 0) -> bool:
        """Store `blob` under `name` unless present.  Returns True when this
        call wrote the chunk, False when it was already stored (a reference,
        the incremental fast path).  `raw_bytes` is the uncompressed payload
        size, credited to the written/referenced byte counters."""
        p = self.root / name
        if p.is_file():
            self.ref(name, raw_bytes or len(blob))
            return False
        self.root.mkdir(parents=True, exist_ok=True)
        # tmp name must be unique per WRITER, and writers can now live in
        # different processes (process-world rank children share one store):
        # thread idents alone collide across forked children — same main
        # thread address — so qualify with the pid too
        tmp = p.with_name(
            p.name + f".tmp{os.getpid()}-{threading.get_ident()}")
        tmp.write_bytes(blob)
        os.replace(tmp, p)
        with self._lock:
            self.stats["chunks_written"] += 1
            self.stats["bytes_written"] += raw_bytes or len(blob)
        return True

    def get(self, name: str) -> bytes:
        return (self.root / name).read_bytes()

    # ------------------------------------------------------------------ gc
    def list_chunks(self) -> Set[str]:
        if not self.root.is_dir():
            return set()
        return {p.name for p in self.root.iterdir()
                if p.is_file() and ".tmp" not in p.name}

    def gc(self, live: Iterable[str]) -> int:
        """Remove every chunk NOT in `live` (the union of chunk names
        referenced by all manifests the caller intends to keep).  Returns
        the number removed.  Stale tmp files are always collected."""
        live = set(live)
        removed = 0
        if not self.root.is_dir():
            return 0
        for p in list(self.root.iterdir()):
            if not p.is_file():
                continue
            if ".tmp" in p.name or p.name not in live:
                try:
                    p.unlink()
                    removed += 1
                except OSError:
                    pass
        with self._lock:
            self.stats["chunks_removed"] += removed
        return removed
