"""Cross-topology restore: checkpoint written under mesh A, restored under
mesh B (the paper §7 'checkpoint on MPICH, restart on OpenMPI', at the
tensor level).

The manifest stores LOGICAL arrays (as shard chunks + index windows); this
module reassembles them and lays them out for the CURRENT mesh/sharding —
any (16,16) <-> (2,16,16) <-> (4,) <-> 1-device move is the same code path.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.serialization import (_leaf_paths, load_leaf,
                                            load_manifest)


def restore_resharded(ckpt_dir: Path, template, shardings=None,
                      verify: bool = True):
    """Restore `template`-shaped tree; if `shardings` (matching tree of
    NamedSharding) is given, every leaf is device_put with its NEW layout.
    The saving mesh is irrelevant — only index windows matter."""
    man = load_manifest(ckpt_dir)
    keys = [k for k, _ in _leaf_paths(template)]
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(keys))
    vals = []
    for k, sh in zip(keys, shard_leaves):
        host = load_leaf(ckpt_dir, man["leaves"][k], verify,
                         codec=man.get("codec", "zstd"))
        vals.append(jax.device_put(host, sh) if sh is not None
                    else jax.device_put(host))
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, vals)


def plan_summary(ckpt_dir: Path) -> dict:
    """What a restore would move: leaves, bytes, source mesh metadata."""
    man = load_manifest(ckpt_dir)
    total = 0
    for e in man["leaves"].values():
        n = 1
        for d in e["shape"]:
            n *= d
        total += n * np.dtype("float32").itemsize if e["dtype"] == "float32" \
            else n * 2
    return {"n_leaves": len(man["leaves"]), "approx_bytes": total,
            "meta": man.get("meta", {})}
