"""Cross-topology restore: checkpoint written under mesh A, restored under
mesh B (the paper §7 'checkpoint on MPICH, restart on OpenMPI', at the
tensor level).

The manifest stores LOGICAL arrays (as shard chunks + index windows); this
module reassembles them and lays them out for the CURRENT mesh/sharding —
any (16,16) <-> (2,16,16) <-> (4,) <-> 1-device move is the same code path.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional

import jax
import numpy as np

from repro.checkpoint.serialization import (_leaf_paths,
                                            iter_restored_leaves,
                                            load_manifest)


def restore_resharded(ckpt_dir: Path, template, shardings=None,
                      verify: bool = True, mesh=None, rules=None,
                      store=None, workers=None, stats=None):
    """Restore `template`-shaped tree; if `shardings` (matching tree of
    NamedSharding) is given, every leaf is device_put with its NEW layout.
    Alternatively pass `mesh` (e.g. from ``elastic.choose_mesh``) plus the
    ``ShardingRules`` in `rules` and the layout is DERIVED per leaf for
    that arbitrary new mesh.  The saving mesh is irrelevant — only index
    windows matter.

    Leaves stream through the bounded restore pool (`workers`, mirroring
    the writer pool): device transfer of leaf k overlaps fetch+decompress
    of the next leaves.  `store` routes chunk reads — a caching backend
    fetches exactly the chunks its cache lacks (the fresh-host restart).
    `stats` accumulates restore_io_s/restore_decompress_s/
    restore_device_s."""
    import time
    man = load_manifest(ckpt_dir)
    keys = [k for k, _ in _leaf_paths(template)]
    if shardings is None and mesh is not None:
        shardings = derive_shardings(template, mesh, rules)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(keys))
    by_key = dict(zip(keys, shard_leaves))
    vals = []
    for k, host in iter_restored_leaves(ckpt_dir, man, keys, verify,
                                        store=store, workers=workers,
                                        stats=stats):
        sh = by_key[k]
        t0 = time.perf_counter()
        vals.append(jax.device_put(host, sh) if sh is not None
                    else jax.device_put(host))
        if stats is not None:
            stats["restore_device_s"] = \
                stats.get("restore_device_s", 0.0) \
                + (time.perf_counter() - t0)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, vals)


def derive_shardings(template, mesh, rules=None):
    """NamedSharding tree for an arbitrary NEW mesh: Pm leaves resolve
    their logical axes through `rules` (delegated to the one canonical
    resolver, ``sharding.param_shardings``, so elastic restores can never
    drift from training layouts); plain array leaves replicate (the safe
    layout on a world whose shape the checkpoint never saw)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.distributed.sharding import param_shardings
    from repro.models.params import is_pm

    def one(leaf):
        if rules is not None and is_pm(leaf):
            return param_shardings(leaf, mesh, rules)
        return NamedSharding(mesh, P())
    return jax.tree.map(one, template, is_leaf=is_pm)


def _dtype_bytes(dtype: str) -> int:
    if dtype == "bfloat16":
        return 2
    try:
        return int(np.dtype(dtype).itemsize)
    except TypeError:
        return 4


def plan_summary(ckpt_dir: Path) -> dict:
    """What a restore would move: leaves, shard chunks, bytes, and where the
    checkpoint came from (source world + membership generation).  For v3
    manifests also reports the content-addressed view: distinct chunks vs
    shard references (replicas and unchanged leaves collapse onto the same
    chunk) and the compressed footprint."""
    man = load_manifest(ckpt_dir)
    total = 0
    n_shards = 0
    chunks = {}
    for e in man["leaves"].values():
        n = 1
        for d in e["shape"]:
            n *= d
        total += n * _dtype_bytes(e["dtype"])
        n_shards += len(e.get("shards", ()))
        for s in e.get("shards", ()):
            if "chunk" in s:
                chunks[s["chunk"]] = s.get("clen", 0)
    meta = man.get("meta", {})
    out = {"n_leaves": len(man["leaves"]), "n_shards": n_shards,
           "approx_bytes": total, "meta": meta,
           "source_world": meta.get("world"),
           "generation": meta.get("generation", 0)}
    if chunks:
        out["n_chunks"] = len(chunks)
        out["compressed_bytes"] = sum(chunks.values())
    return out
