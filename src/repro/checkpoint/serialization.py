"""Per-shard checkpoint serialization.

Each leaf of the state pytree is written as one file PER DEVICE SHARD
(index-range-addressed, zstd-compressed), plus a JSON manifest holding the
tree structure, global shapes/dtypes, shard index maps and crc32s.  This is
the layout a real fleet writes (every host stores its addressable shards);
restore reassembles logical arrays from chunks and lays them out for
whatever mesh is current — the paper's cross-implementation restart at the
tensor level.
"""
from __future__ import annotations

import json
import os
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

try:                                    # zstandard is optional: fall back to
    import zstandard                    # zlib so the core C/R path has no
    HAVE_ZSTD = True                    # dependency beyond the stdlib
except ImportError:                     # pragma: no cover - env dependent
    zstandard = None
    HAVE_ZSTD = False


class _ZlibCompressor:
    def compress(self, data: bytes) -> bytes:
        return zlib.compress(data, 6)


class _ZlibDecompressor:
    def decompress(self, blob: bytes) -> bytes:
        return zlib.decompress(blob)


def _codec_pair(codec: str):
    """(compressor, decompressor) for a manifest codec name."""
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise RuntimeError(
                "checkpoint written with zstd but zstandard is not installed")
        return zstandard.ZstdCompressor(level=3), zstandard.ZstdDecompressor()
    if codec == "zlib":
        return _ZlibCompressor(), _ZlibDecompressor()
    raise ValueError(f"unknown checkpoint codec {codec!r}")


DEFAULT_CODEC = "zstd" if HAVE_ZSTD else "zlib"


class HostArray:
    """Synchronous device->host snapshot of a (possibly sharded) jax.Array.
    Taken BEFORE the async writer runs, so buffer donation in the next
    train step can't corrupt the checkpoint."""

    def __init__(self, x):
        self.shape = tuple(x.shape)
        self.dtype = str(x.dtype)
        self.shards = []
        for sh in x.addressable_shards:
            idx = [[s.start or 0,
                    s.stop if s.stop is not None else x.shape[d]]
                   for d, s in enumerate(sh.index)] if x.ndim else []
            self.shards.append((idx, np.asarray(sh.data).copy(),
                                int(sh.device.id)))


def snapshot_to_host(tree):
    """jax.Array leaves -> HostArray; everything else -> np copy."""
    def conv(x):
        if isinstance(x, jax.Array):
            return HostArray(x)
        return np.asarray(x).copy()
    return jax.tree.map(conv, tree)


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_key_str(k) for k in path)
        out.append((key, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _atomic_write(path: Path, data: bytes) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)


def save_shards(ckpt_dir: Path, state, meta: Optional[dict] = None,
                codec: Optional[str] = None) -> dict:
    """Write every addressable shard of every leaf.  Returns the manifest
    (already committed to disk, LAST, for atomicity)."""
    codec = codec or DEFAULT_CODEC
    cctx, _ = _codec_pair(codec)
    ext = "zst" if codec == "zstd" else "zz"
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    leaves = _leaf_paths(state)
    manifest: Dict[str, Any] = {"version": 1, "codec": codec, "leaves": {},
                                "meta": meta or {}}
    for i, (key, leaf) in enumerate(leaves):
        arr = leaf
        entry: Dict[str, Any] = {}
        if isinstance(arr, jax.Array):
            arr = HostArray(arr)
        if isinstance(arr, HostArray):
            entry["shape"] = list(arr.shape)
            entry["dtype"] = arr.dtype
            shards = []
            # de-dup replicated shards FIRST (write one per index window)
            uniq_src = {}
            for idx, data, dev in arr.shards:
                uniq_src.setdefault(json.dumps(idx), (idx, data, dev))
            for idx, data, dev in uniq_src.values():
                blob = cctx.compress(data.tobytes())
                fname = f"leaf{i:05d}_shard{dev:04d}.{ext}"
                _atomic_write(ckpt_dir / fname, blob)
                shards.append({"file": fname, "index": idx,
                               "crc32": zlib.crc32(blob), "device": dev})
            entry["shards"] = shards
        else:
            data = np.asarray(arr)
            entry["shape"] = list(data.shape)
            entry["dtype"] = str(data.dtype)
            blob = cctx.compress(data.tobytes())
            fname = f"leaf{i:05d}_full.{ext}"
            _atomic_write(ckpt_dir / fname, blob)
            entry["shards"] = [{"file": fname,
                                "index": [[0, d] for d in data.shape],
                                "crc32": zlib.crc32(blob), "device": -1}]
        manifest["leaves"][key] = entry
    _atomic_write(ckpt_dir / "MANIFEST.json",
                  json.dumps(manifest, indent=1).encode())
    return manifest


def load_manifest(ckpt_dir: Path) -> dict:
    return json.loads((ckpt_dir / "MANIFEST.json").read_text())


def load_leaf(ckpt_dir: Path, entry: dict, verify: bool = True,
              codec: Optional[str] = None) -> np.ndarray:
    """Reassemble one logical array from its shard chunks.  `codec` must be
    the manifest's — pass ``manifest.get("codec", "zstd")`` (pre-codec
    manifests were always zstd); guessing here would decompress with the
    wrong codec."""
    if codec is None:
        raise ValueError(
            'pass the manifest codec: manifest.get("codec", "zstd")')
    _, dctx = _codec_pair(codec)
    shape = tuple(entry["shape"])
    dtype = np.dtype(entry["dtype"]) if entry["dtype"] != "bfloat16" else None
    # bfloat16 round-trips through jnp below; read raw bytes as uint16
    import jax.numpy as jnp
    jdt = jnp.dtype(entry["dtype"])
    out = np.zeros(shape, dtype=jdt)
    for s in entry["shards"]:
        blob = (ckpt_dir / s["file"]).read_bytes()
        if verify and zlib.crc32(blob) != s["crc32"]:
            raise IOError(f"{s['file']}: crc mismatch")
        raw = dctx.decompress(blob)
        idx = tuple(slice(a, b) for a, b in s["index"])
        window = out[idx].shape if idx else ()
        chunk = np.frombuffer(raw, dtype=jdt).reshape(window or shape)
        if idx:
            out[idx] = chunk
        else:
            out = chunk.reshape(shape).copy()
    return out


def restore_tree(ckpt_dir: Path, template, verify: bool = True):
    """Restore into the structure of `template` (values ignored; tree shape
    and leaf order must match what was saved)."""
    man = load_manifest(ckpt_dir)
    keys = [k for k, _ in _leaf_paths(template)]
    missing = [k for k in keys if k not in man["leaves"]]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}")
    codec = man.get("codec", "zstd")
    vals = [load_leaf(ckpt_dir, man["leaves"][k], verify, codec=codec)
            for k in keys]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, vals)


def validate(ckpt_dir: Path) -> bool:
    try:
        man = load_manifest(ckpt_dir)
        for entry in man["leaves"].values():
            for s in entry["shards"]:
                blob = (ckpt_dir / s["file"]).read_bytes()
                if zlib.crc32(blob) != s["crc32"]:
                    return False
        return True
    except (OSError, KeyError, json.JSONDecodeError):
        return False
