"""Per-shard checkpoint serialization over a content-addressed chunk store.

Each leaf of the state pytree is written as one chunk PER DEVICE SHARD
(index-range-addressed, compressed), named by the digest of its
uncompressed bytes and stored in a ``chunks/`` directory; a JSON manifest
(v3) holds the tree structure, global shapes/dtypes and shard index maps,
referencing chunks BY NAME.  A save where only a few leaves changed since
the previous step writes only the changed chunks and hard-references the
rest (DESIGN.md §9) — the incremental/differential checkpointing that
dominates C/R cost at scale (MANA; Adam et al., PAPERS.md).

The write path is a pipelined parallel writer: shard jobs
(hash → store-hit check → compress → atomic write) run on a thread pool;
zlib/zstd release the GIL during compression, and compression reads from
memoryviews of the host snapshot (no ``tobytes`` copy).

Restore reassembles logical arrays from chunks and lays them out for
whatever mesh is current — the paper's cross-implementation restart at the
tensor level.  Manifest v1 checkpoints (pre-chunk-store, one ``leaf*``
file per shard with crc32s) are still readable.
"""
from __future__ import annotations

import json
import os
import time
import zlib
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.checkpoint.chunkstore import ChunkStore, content_digest

try:                                    # zstandard is optional: fall back to
    import zstandard                    # zlib so the core C/R path has no
    HAVE_ZSTD = True                    # dependency beyond the stdlib
except ImportError:                     # pragma: no cover - env dependent
    zstandard = None
    HAVE_ZSTD = False


class _ZlibCompressor:
    def compress(self, data) -> bytes:
        return zlib.compress(data, 6)


class _ZlibDecompressor:
    def decompress(self, blob: bytes) -> bytes:
        return zlib.decompress(blob)


def _codec_pair(codec: str):
    """(compressor, decompressor) for a manifest codec name."""
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise RuntimeError(
                "checkpoint written with zstd but zstandard is not installed")
        return zstandard.ZstdCompressor(level=3), zstandard.ZstdDecompressor()
    if codec == "zlib":
        return _ZlibCompressor(), _ZlibDecompressor()
    raise ValueError(f"unknown checkpoint codec {codec!r}")


DEFAULT_CODEC = "zstd" if HAVE_ZSTD else "zlib"

#: default writer-pool width; compression releases the GIL so threads give
#: real parallelism.  Kept modest: past the storage bandwidth more threads
#: only add contention.
DEFAULT_WORKERS = min(8, os.cpu_count() or 1)

#: adaptive compression: probe-compress this much of a chunk first, and if
#: the probe stays above INCOMPRESSIBLE_RATIO store the chunk RAW (ext
#: ``.raw``) — trained float32/bf16 weights are near-random bytes, and
#: running deflate over them costs ~40ms/MB to save a few percent.  The
#: chunk name (content digest of the UNCOMPRESSED bytes) is unchanged, so
#: integrity and incremental dedup work identically for raw chunks.
INCOMPRESSIBLE_SAMPLE = 1 << 16
INCOMPRESSIBLE_RATIO = 0.9


def _codec_ext(codec: str) -> str:
    return "zst" if codec == "zstd" else "zz"


class HostArray:
    """Synchronous device->host snapshot of a (possibly sharded) jax.Array.
    Taken BEFORE the async writer runs, so buffer donation in the next
    train step can't corrupt the checkpoint.

    Replicated shards are deduplicated by index window BEFORE the
    device->host copy: a leaf replicated over N devices costs one transfer
    and one host buffer, not N transfers discarded at write time."""

    def __init__(self, x):
        self.shape = tuple(x.shape)
        self.dtype = str(x.dtype)
        self.shards = []
        seen = set()
        for sh in x.addressable_shards:
            idx = [[s.start or 0,
                    s.stop if s.stop is not None else x.shape[d]]
                   for d, s in enumerate(sh.index)] if x.ndim else []
            key = tuple(tuple(w) for w in idx)
            if key in seen:
                continue
            seen.add(key)
            self.shards.append((idx, np.asarray(sh.data).copy(),
                                int(sh.device.id)))


def snapshot_to_host(tree):
    """jax.Array leaves -> HostArray; everything else -> np copy."""
    def conv(x):
        if isinstance(x, jax.Array):
            return HostArray(x)
        return np.asarray(x).copy()
    return jax.tree.map(conv, tree)


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_key_str(k) for k in path)
        out.append((key, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _atomic_write(path: Path, data: bytes) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)


def _as_buffer(data: np.ndarray):
    """Flat byte memoryview of an array — compression and hashing read the
    host snapshot in place instead of through a ``tobytes()`` copy."""
    if not data.flags.c_contiguous:
        data = np.ascontiguousarray(data)
    if data.ndim == 0:           # 0-d arrays: one scalar, copy is free
        return memoryview(data.tobytes())
    try:
        return data.data.cast("B")
    except (ValueError, BufferError):
        # dtypes outside the buffer protocol (bfloat16 etc.): reinterpret
        # the same memory as raw bytes — still no copy
        return data.view(np.uint8).data


def _write_shard(store: ChunkStore, codec: str, ext: str, data: np.ndarray,
                 idx: list, dev: int) -> Tuple[dict, tuple]:
    """One pipeline job: hash -> store-hit check -> (probe ->) compress ->
    write.  Runs on a pool thread; returns (manifest shard entry, stage
    timings).  A chunk may land compressed (``.<codec ext>``) or raw
    (``.raw``, incompressible payload) — the extension is authoritative at
    read time, the digest covers the uncompressed bytes either way."""
    t0 = time.perf_counter()
    buf = _as_buffer(data)
    digest = content_digest(buf)
    t1 = time.perf_counter()
    for ext_try in (ext, "raw"):         # incremental hit: reference only
        name = f"{digest}.{ext_try}"
        if store.has(name):
            store.ref(name, buf.nbytes)
            clen = store.size(name)
            t2 = t3 = time.perf_counter()
            return ({"chunk": name, "index": idx, "device": dev,
                     "clen": clen, "raw": buf.nbytes},
                    (t1 - t0, t2 - t1, t3 - t2))
    # compressor per job, created only when actually compressing: a
    # ZstdCompressor wraps one native context and is NOT safe for
    # concurrent use across pool threads (zlib's module function is)
    cctx, _ = _codec_pair(codec)
    sample = (buf[:INCOMPRESSIBLE_SAMPLE]
              if buf.nbytes > INCOMPRESSIBLE_SAMPLE else buf)
    probe = cctx.compress(sample)
    if len(probe) >= INCOMPRESSIBLE_RATIO * sample.nbytes:
        name, blob = f"{digest}.raw", buf          # store uncompressed
    elif sample.nbytes == buf.nbytes:
        name, blob = f"{digest}.{ext}", probe      # probe WAS the payload
    else:
        name, blob = f"{digest}.{ext}", cctx.compress(buf)
    t2 = time.perf_counter()
    store.put(name, blob, raw_bytes=buf.nbytes)
    t3 = time.perf_counter()
    return ({"chunk": name, "index": idx, "device": dev,
             "clen": len(blob), "raw": buf.nbytes},
            (t1 - t0, t2 - t1, t3 - t2))


def save_shards(ckpt_dir: Path, state, meta: Optional[dict] = None,
                codec: Optional[str] = None,
                store: Optional[ChunkStore] = None,
                workers: Optional[int] = None,
                stats: Optional[dict] = None) -> dict:
    """Write every addressable shard of every leaf into the chunk store and
    commit a v3 manifest (LAST, for atomicity).  Returns the manifest.

    `store` defaults to ``ckpt_dir/chunks`` (a self-contained checkpoint);
    a CheckpointManager passes its root-level store so consecutive steps
    share unchanged chunks.  `workers` sizes the compress/write pool
    (<=1 runs inline).  `stats`, when given, accumulates per-stage timings
    (hash_s/compress_s/io_s).
    """
    codec = codec or DEFAULT_CODEC
    _codec_pair(codec)                   # fail fast on an unknown codec
    ext = _codec_ext(codec)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    if store is None:
        store = ChunkStore(ckpt_dir / "chunks")
    workers = DEFAULT_WORKERS if workers is None else workers
    chunk_dir = os.path.relpath(store.root, ckpt_dir)
    leaves = _leaf_paths(state)
    manifest: Dict[str, Any] = {"version": 3, "codec": codec,
                                "chunk_dir": chunk_dir, "leaves": {},
                                "meta": meta or {}}

    jobs: List[Tuple[str, Any]] = []     # (leaf_key, future-or-result)

    def submit(pool, key, data, idx, dev):
        if pool is None:
            jobs.append((key, _write_shard(store, codec, ext, data, idx,
                                           dev)))
        else:
            jobs.append((key, pool.submit(_write_shard, store, codec, ext,
                                          data, idx, dev)))

    pool = ThreadPoolExecutor(max_workers=workers,
                              thread_name_prefix="ckpt-compress") \
        if workers > 1 else None
    try:
        for key, leaf in leaves:
            arr = leaf
            if isinstance(arr, jax.Array):
                arr = HostArray(arr)
            entry: Dict[str, Any] = {}
            if isinstance(arr, HostArray):
                entry["shape"] = list(arr.shape)
                entry["dtype"] = arr.dtype
                # replicas were deduped at snapshot; dedup again here for
                # HostArrays built by older callers
                uniq: Dict[str, tuple] = {}
                for idx, data, dev in arr.shards:
                    uniq.setdefault(json.dumps(idx), (idx, data, dev))
                for idx, data, dev in uniq.values():
                    submit(pool, key, data, idx, dev)
            else:
                data = np.asarray(arr)
                entry["shape"] = list(data.shape)
                entry["dtype"] = str(data.dtype)
                submit(pool, key, data, [[0, d] for d in data.shape], -1)
            manifest["leaves"][key] = entry
        # collect in submission order so manifests are deterministic
        per_leaf: Dict[str, List[dict]] = {}
        for key, job in jobs:
            ent, (dh, dc, dio) = job if isinstance(job, tuple) \
                else job.result()
            per_leaf.setdefault(key, []).append(ent)
            if stats is not None:
                stats["hash_s"] = stats.get("hash_s", 0.0) + dh
                stats["compress_s"] = stats.get("compress_s", 0.0) + dc
                stats["io_s"] = stats.get("io_s", 0.0) + dio
        for key, shards in per_leaf.items():
            manifest["leaves"][key]["shards"] = shards
    finally:
        if pool is not None:
            pool.shutdown(wait=True)
    _atomic_write(ckpt_dir / "MANIFEST.json",
                  json.dumps(manifest, indent=1).encode())
    return manifest


def load_manifest(ckpt_dir: Path) -> dict:
    return json.loads((ckpt_dir / "MANIFEST.json").read_text())


def manifest_chunks(man: dict) -> List[str]:
    """Every chunk name a v3 manifest references (refcount-gc input).
    Empty for v1 manifests (their blobs live inside the step dir)."""
    if man.get("version", 1) < 3:
        return []
    return [s["chunk"] for e in man.get("leaves", {}).values()
            for s in e.get("shards", ())]


def _shard_path(ckpt_dir: Path, man_or_chunk_dir, s: dict) -> Path:
    """Resolve a shard entry to its file: v3 entries name a chunk in the
    manifest's chunk_dir; v1 entries name a file inside the step dir."""
    if "chunk" in s:
        chunk_dir = (man_or_chunk_dir.get("chunk_dir", "chunks")
                     if isinstance(man_or_chunk_dir, dict)
                     else man_or_chunk_dir)
        return ckpt_dir / chunk_dir / s["chunk"]
    return ckpt_dir / s["file"]


def load_leaf(ckpt_dir: Path, entry: dict, verify: bool = True,
              codec: Optional[str] = None,
              chunk_dir: str = "chunks") -> np.ndarray:
    """Reassemble one logical array from its shard chunks.  `codec` must be
    the manifest's — pass ``manifest.get("codec", "zstd")`` (pre-codec
    manifests were always zstd); guessing here would decompress with the
    wrong codec.  `chunk_dir` is the manifest's (v3)."""
    if codec is None:
        raise ValueError(
            'pass the manifest codec: manifest.get("codec", "zstd")')
    _, dctx = _codec_pair(codec)
    shape = tuple(entry["shape"])
    # bfloat16 round-trips through jnp below; read raw bytes as uint16
    import jax.numpy as jnp
    jdt = jnp.dtype(entry["dtype"])
    out = np.zeros(shape, dtype=jdt)
    for s in entry["shards"]:
        path = _shard_path(ckpt_dir, chunk_dir, s)
        blob = path.read_bytes()
        if verify and "file" in s and zlib.crc32(blob) != s["crc32"]:
            raise IOError(f"{s['file']}: crc mismatch")
        raw = (blob if s.get("chunk", "").endswith(".raw")
               else dctx.decompress(blob))
        if verify and "chunk" in s:
            # chunks are self-validating: the name IS the content digest
            if content_digest(raw) != s["chunk"].split(".")[0]:
                raise IOError(f"{s['chunk']}: content digest mismatch")
        idx = tuple(slice(a, b) for a, b in s["index"])
        window = out[idx].shape if idx else ()
        chunk = np.frombuffer(raw, dtype=jdt).reshape(window or shape)
        if idx:
            out[idx] = chunk
        else:
            out = chunk.reshape(shape).copy()
    return out


def restore_tree(ckpt_dir: Path, template, verify: bool = True):
    """Restore into the structure of `template` (values ignored; tree shape
    and leaf order must match what was saved)."""
    man = load_manifest(ckpt_dir)
    keys = [k for k, _ in _leaf_paths(template)]
    missing = [k for k in keys if k not in man["leaves"]]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}")
    codec = man.get("codec", "zstd")
    chunk_dir = man.get("chunk_dir", "chunks")
    vals = [load_leaf(ckpt_dir, man["leaves"][k], verify, codec=codec,
                      chunk_dir=chunk_dir)
            for k in keys]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, vals)


def validate(ckpt_dir: Path, deep: bool = False) -> bool:
    """Checkpoint-dir validity.

    v3 fast path (the default): parse the manifest and stat every
    referenced chunk (exists + recorded compressed length) — no blob is
    read or decompressed, so ``latest_valid`` over a long history is
    manifest-only.  ``deep=True`` additionally decompresses every chunk
    and re-derives its content digest (what restore enforces anyway).
    v1 dirs always get the full crc32 read (their manifests carry no
    sizes)."""
    try:
        man = load_manifest(ckpt_dir)
        for entry in man["leaves"].values():
            for s in entry["shards"]:
                path = _shard_path(ckpt_dir, man, s)
                if "chunk" in s:
                    if not path.is_file():
                        return False
                    if path.stat().st_size != s["clen"]:
                        return False
                    if deep:
                        try:
                            blob = path.read_bytes()
                            if s["chunk"].endswith(".raw"):
                                raw = blob
                            else:
                                _, dctx = _codec_pair(
                                    man.get("codec", "zstd"))
                                raw = dctx.decompress(blob)
                        except Exception:    # any corruption-shaped failure
                            return False
                        if content_digest(raw) != s["chunk"].split(".")[0]:
                            return False
                else:
                    if zlib.crc32(path.read_bytes()) != s["crc32"]:
                        return False
        return True
    except (OSError, KeyError, json.JSONDecodeError, ValueError,
            RuntimeError):
        return False
