"""Per-shard checkpoint serialization over a content-addressed chunk store.

Each leaf of the state pytree is written as one chunk PER DEVICE SHARD
(index-range-addressed, compressed), named by the digest of its
uncompressed bytes and stored through a ``ChunkStoreBackend`` — a local
directory, or a socket chunk service with a local cache
(checkpoint/chunkservice.py, DESIGN.md §11); a JSON manifest (v3) holds
the tree structure, global shapes/dtypes and shard index maps,
referencing chunks BY NAME.  A save where only a few leaves changed since
the previous step writes only the changed chunks and hard-references the
rest (DESIGN.md §9) — the incremental/differential checkpointing that
dominates C/R cost at scale (MANA; Adam et al., PAPERS.md).

The write path is a pipelined parallel writer: shard jobs
(hash → store-hit check → probe → compress → atomic write) run on a
thread pool; zlib/zstd release the GIL during compression, and
compression reads from memoryviews of the host snapshot (no ``tobytes``
copy).  Multi-byte float shards are byte-transposed (shuffle filter)
before the probe when that wins — recorded per chunk in the manifest and
in the chunk extension.  Against a store that ``wants_batched_has``
(networked), the hit checks for a whole save collapse into ONE
``has_many`` round trip between the hash and compress stages.

The restore path mirrors the writer: leaves are fetched + decompressed a
bounded pool ahead of the consumer, so device transfer of leaf k overlaps
fetch/decompress of leaf k+1; chunk reads go cache → local dir → the
manifest's recorded store spec (fetch-on-miss).  Restore reassembles
logical arrays from chunks and lays them out for whatever mesh is
current — the paper's cross-implementation restart at the tensor level.
Manifest v1 checkpoints (pre-chunk-store, one ``leaf*`` file per shard
with crc32s) are still readable.
"""
from __future__ import annotations

import json
import os
import re
import time
import zlib
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.checkpoint import chunkstore
from repro.checkpoint.chunkstore import (ChunkReader, ChunkStoreBackend,
                                         content_digest)

try:                                    # zstandard is optional: fall back to
    import zstandard                    # zlib so the core C/R path has no
    HAVE_ZSTD = True                    # dependency beyond the stdlib
except ImportError:                     # pragma: no cover - env dependent
    zstandard = None
    HAVE_ZSTD = False


class _ZlibCompressor:
    def compress(self, data) -> bytes:
        return zlib.compress(data, 6)


class _ZlibDecompressor:
    def decompress(self, blob: bytes) -> bytes:
        return zlib.decompress(blob)


def _codec_pair(codec: str):
    """(compressor, decompressor) for a manifest codec name."""
    if codec == "zstd":
        if not HAVE_ZSTD:
            raise RuntimeError(
                "checkpoint written with zstd but zstandard is not installed")
        return zstandard.ZstdCompressor(level=3), zstandard.ZstdDecompressor()
    if codec == "zlib":
        return _ZlibCompressor(), _ZlibDecompressor()
    raise ValueError(f"unknown checkpoint codec {codec!r}")


DEFAULT_CODEC = "zstd" if HAVE_ZSTD else "zlib"

#: default writer-pool width; compression releases the GIL so threads give
#: real parallelism.  Kept modest: past the storage bandwidth more threads
#: only add contention.  The restore pool mirrors this.
DEFAULT_WORKERS = min(8, os.cpu_count() or 1)

#: adaptive compression: probe-compress a sample of a chunk first, and if
#: the probe stays above INCOMPRESSIBLE_RATIO store the chunk RAW (ext
#: ``.raw``) — trained float32/bf16 weights are near-random bytes, and
#: running deflate over them costs ~40ms/MB to save a few percent.  The
#: chunk name (content digest of the UNCOMPRESSED bytes) is unchanged, so
#: integrity and incremental dedup work identically for raw chunks.
#:
#: The sample is BOTH capped (INCOMPRESSIBLE_SAMPLE) and fractional
#: (1/PROBE_FRACTION of the chunk, floored at PROBE_MIN_SAMPLE): a flat
#: 64 KiB cap alone meant a chunk of exactly that size paid a FULL
#: deflate pass just to decide "store raw" — on zlib fallback hosts the
#: probe then cost as much as the seed writer's whole compression, and a
#: 1-worker pool had no parallelism to win it back (the PR-6 smoke-floor
#: regression).  Chunks at or below PROBE_MIN_SAMPLE are still probed
#: whole, so a compressible small chunk keeps the probe-is-the-payload
#: single pass.
INCOMPRESSIBLE_SAMPLE = 1 << 16
INCOMPRESSIBLE_RATIO = 0.9
PROBE_MIN_SAMPLE = 1 << 13
PROBE_FRACTION = 8

#: byte-shuffle probe economics, three gates in increasing cost:
#:
#:   1. TOP_BYTES — the filter's entire win is a low-entropy top
#:      (sign+exponent) byte plane, so count distinct top bytes over the
#:      sample (~20us) first; wide-range floats (many exponents in play:
#:      unit-variance float32 weights measure 12-15 distinct) skip the
#:      compression probe entirely and keep the raw path's zero cost.
#:   2. the shuffled probe runs on a SMALLER sample (an eighth of the
#:      plain one — the plane structure shows at any size);
#:   3. the shuffled path is taken only when it beats the plain ratio by
#:      a clear MARGIN — it costs a strided full-buffer copy plus a
#:      compression pass over data the plain probe may have stored raw
#:      for free.  Near-constant-exponent payloads (uniform/narrow-range
#:      floats, most float64) probe 0.05-0.07+ better and pay off.
BYTE_SHUFFLE_SAMPLE = 1 << 13
BYTE_SHUFFLE_MARGIN = 0.04
BYTE_SHUFFLE_TOP_BYTES = 8


def _codec_ext(codec: str) -> str:
    return "zst" if codec == "zstd" else "zz"


#: chunk extensions are authoritative at read time — a store can hold the
#: same digest under several encodings and every one decodes to the same
#: bytes.  Plain: ``zst``/``zz``; shuffled carries its byte width IN THE
#: NAME (``zsts4``/``zzs8``), so a store hit can never be decoded with a
#: width other than the one it was written with (the unshuffle inverts
#: the writer's permutation and yields the original bytes whatever dtype
#: the READER reassembles them into).
_EXT_PLAIN = {"zst": "zstd", "zz": "zlib"}
_EXT_SHUF = re.compile(r"^(zst|zz)s(\d+)$")


# ------------------------------------------------------ byte-shuffle filter

def _shuffle_itemsize(dtype) -> int:
    """Element width when the byte-transpose filter applies (multi-byte
    floats: sign/exponent bytes repeat across elements and compress well
    once grouped; mantissa bytes stay random but now sit together), else
    0.  bfloat16 is an extension dtype (kind 'V'), matched by name."""
    if dtype.kind == "f" or dtype.name == "bfloat16":
        return dtype.itemsize if dtype.itemsize > 1 else 0
    return 0


def _shuffled(buf, itemsize: int) -> bytes:
    """Byte transpose: [e0b0 e0b1 e1b0 e1b1 ...] -> [all b0s][all b1s].
    One copy, the same cost class as the ``tobytes`` the writer already
    avoids elsewhere — paid only when the probe says it wins."""
    a = np.frombuffer(buf, dtype=np.uint8)
    return a.reshape(-1, itemsize).T.tobytes()


def _unshuffled(raw: bytes, itemsize: int) -> bytes:
    a = np.frombuffer(raw, dtype=np.uint8)
    return a.reshape(itemsize, -1).T.tobytes()


def _top_plane_narrow(buf, itemsize: int) -> bool:
    """Cheap shuffle-probe gate: True when the top (sign+exponent on
    little-endian) byte plane of the sample holds few distinct values —
    the precondition for the transpose to win (BYTE_SHUFFLE_TOP_BYTES)."""
    top = np.frombuffer(buf, dtype=np.uint8)[itemsize - 1::itemsize]
    return np.unique(top).size <= BYTE_SHUFFLE_TOP_BYTES


def decode_chunk(name: str, blob: bytes, codec: str) -> bytes:
    """Chunk file bytes -> original uncompressed bytes, keyed by the chunk
    extension (``raw``/``bin`` = stored as-is; ``zsts<N>``/``zzs<N>`` =
    compressed, byte-shuffled with width N).  `codec` is only the
    fallback for extensions outside the map (v3 manifests written before
    the map)."""
    ext = name.rsplit(".", 1)[-1]
    if ext in ("raw", "bin"):
        return blob
    shuf = _EXT_SHUF.match(ext)
    base = (_EXT_PLAIN[shuf.group(1)] if shuf
            else _EXT_PLAIN.get(ext, codec))
    _, dctx = _codec_pair(base)
    raw = dctx.decompress(blob)
    if shuf:
        raw = _unshuffled(raw, int(shuf.group(2)))
    return raw


class HostArray:
    """Synchronous device->host snapshot of a (possibly sharded) jax.Array.
    Taken BEFORE the async writer runs, so buffer donation in the next
    train step can't corrupt the checkpoint.

    Replicated shards are deduplicated by index window BEFORE the
    device->host copy: a leaf replicated over N devices costs one transfer
    and one host buffer, not N transfers discarded at write time."""

    def __init__(self, x):
        self.shape = tuple(x.shape)
        self.dtype = str(x.dtype)
        self.shards = []
        seen = set()
        for sh in x.addressable_shards:
            idx = [[s.start or 0,
                    s.stop if s.stop is not None else x.shape[d]]
                   for d, s in enumerate(sh.index)] if x.ndim else []
            key = tuple(tuple(w) for w in idx)
            if key in seen:
                continue
            seen.add(key)
            self.shards.append((idx, np.asarray(sh.data).copy(),
                                int(sh.device.id)))


def snapshot_to_host(tree):
    """jax.Array leaves -> HostArray; everything else -> np copy."""
    def conv(x):
        if isinstance(x, jax.Array):
            return HostArray(x)
        return np.asarray(x).copy()
    return jax.tree.map(conv, tree)


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_key_str(k) for k in path)
        out.append((key, leaf))
    return out


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def _atomic_write(path: Path, data: bytes) -> None:
    tmp = path.with_name(path.name + ".tmp")
    tmp.write_bytes(data)
    os.replace(tmp, path)


def _as_buffer(data: np.ndarray):
    """Flat byte memoryview of an array — compression and hashing read the
    host snapshot in place instead of through a ``tobytes()`` copy."""
    if not data.flags.c_contiguous:
        data = np.ascontiguousarray(data)
    if data.ndim == 0:           # 0-d arrays: one scalar, copy is free
        return memoryview(data.tobytes())
    try:
        return data.data.cast("B")
    except (ValueError, BufferError):
        # dtypes outside the buffer protocol (bfloat16 etc.): reinterpret
        # the same memory as raw bytes — still no copy
        return data.view(np.uint8).data


# ------------------------------------------------------------ write pipeline

def _hit_candidates(digest: str, ext: str, itemsize: int) -> List[str]:
    """Every name a previous save could have stored this content under
    (order = preference).  The digest covers the UNSHUFFLED uncompressed
    bytes, so all encodings of one content share one digest."""
    names = [f"{digest}.{ext}s{itemsize}"] if itemsize else []
    return names + [f"{digest}.{ext}", f"{digest}.raw"]


def _shard_codec(name: str) -> Optional[str]:
    """Per-chunk manifest codec record (e.g. ``"zstd+shuf4"``) for
    filtered chunks; None when the manifest-level codec fully describes
    the chunk.  Derived from the extension, which is authoritative."""
    shuf = _EXT_SHUF.match(name.rsplit(".", 1)[-1])
    return (f"{_EXT_PLAIN[shuf.group(1)]}+shuf{shuf.group(2)}"
            if shuf else None)


def _hash_shard(data: np.ndarray):
    t0 = time.perf_counter()
    buf = _as_buffer(data)
    digest = content_digest(buf)
    return buf, digest, time.perf_counter() - t0


def _finish_shard(store: ChunkStoreBackend, codec: str, ext: str,
                  buf, digest: str, itemsize: int, idx: list, dev: int,
                  presence: Optional[Dict[str, int]] = None
                  ) -> Tuple[dict, tuple]:
    """Store-hit check -> (probe ->) compress -> write for one hashed
    shard.  `presence` ({name: clen}, from one batched has_many covering
    the whole save) replaces per-chunk store.has round trips when the
    backend is networked; None falls back to per-call checks."""
    def entry(name: str, clen: int) -> dict:
        e = {"chunk": name, "index": idx, "device": dev,
             "clen": clen, "raw": buf.nbytes}
        codec_rec = _shard_codec(name)
        if codec_rec:
            e["codec"] = codec_rec
        return e

    t1 = time.perf_counter()
    for name in _hit_candidates(digest, ext, itemsize):
        clen = (presence.get(name) if presence is not None
                else (store.size(name) if store.has(name) else None))
        if clen is not None:             # incremental hit: reference only
            store.ref(name, buf.nbytes)
            t2 = t3 = time.perf_counter()
            return entry(name, clen), (0.0, t2 - t1, t3 - t2)
    # compressor per job, created only when actually compressing: a
    # ZstdCompressor wraps one native context and is NOT safe for
    # concurrent use across pool threads (zlib's module function is)
    cctx, _ = _codec_pair(codec)
    probe_len = min(INCOMPRESSIBLE_SAMPLE,
                    max(PROBE_MIN_SAMPLE, buf.nbytes // PROBE_FRACTION))
    sample = buf[:probe_len] if buf.nbytes > probe_len else buf
    probe = cctx.compress(sample)
    shuf_ratio = None
    if itemsize and buf.nbytes % itemsize == 0:
        aligned = min(sample.nbytes, BYTE_SHUFFLE_SAMPLE)
        aligned -= aligned % itemsize
        if aligned and _top_plane_narrow(sample[:aligned], itemsize):
            shuf_probe = cctx.compress(_shuffled(sample[:aligned],
                                                 itemsize))
            shuf_ratio = len(shuf_probe) / aligned
    plain_ratio = len(probe) / sample.nbytes
    whole = sample.nbytes == buf.nbytes
    if (shuf_ratio is not None
            and shuf_ratio < plain_ratio - BYTE_SHUFFLE_MARGIN
            and shuf_ratio < INCOMPRESSIBLE_RATIO):
        name = f"{digest}.{ext}s{itemsize}"
        blob = cctx.compress(_shuffled(buf, itemsize))
    elif plain_ratio >= INCOMPRESSIBLE_RATIO:
        name, blob = f"{digest}.raw", buf          # store uncompressed
    elif whole:
        name, blob = f"{digest}.{ext}", probe      # probe WAS the payload
    else:
        name, blob = f"{digest}.{ext}", cctx.compress(buf)
    t2 = time.perf_counter()
    store.put(name, blob, raw_bytes=buf.nbytes)
    if presence is not None:
        # a later duplicate-digest shard IN THIS SAVE references instead
        # of re-compressing/re-uploading (the snapshot was pre-save)
        presence[name] = len(blob)
    t3 = time.perf_counter()
    return entry(name, len(blob)), (0.0, t2 - t1, t3 - t2)


def _write_shard(store: ChunkStoreBackend, codec: str, ext: str,
                 data: np.ndarray, idx: list, dev: int) -> Tuple[dict, tuple]:
    """One single-pass pipeline job (local stores): hash -> store-hit
    check -> (probe ->) compress -> write.  Runs on a pool thread;
    returns (manifest shard entry, stage timings)."""
    buf, digest, dh = _hash_shard(data)
    itemsize = _shuffle_itemsize(data.dtype)
    ent, (_, dc, dio) = _finish_shard(store, codec, ext, buf, digest,
                                      itemsize, idx, dev)
    return ent, (dh, dc, dio)


def save_shards(ckpt_dir: Path, state, meta: Optional[dict] = None,
                codec: Optional[str] = None,
                store: Optional[ChunkStoreBackend] = None,
                workers: Optional[int] = None,
                stats: Optional[dict] = None) -> dict:
    """Write every addressable shard of every leaf into the chunk store and
    commit a v3 manifest (LAST, for atomicity).  Returns the manifest.

    `store` defaults to ``ckpt_dir/chunks`` (a self-contained checkpoint);
    a CheckpointManager passes its root-level store so consecutive steps
    share unchanged chunks — possibly a remote/caching backend, whose spec
    the manifest records for fetch-on-miss readers.  Against a store that
    ``wants_batched_has`` the per-shard hit checks become one ``has_many``
    round trip between the hash and compress stages.  `workers` sizes the
    compress/write pool (<=1 runs inline).  `stats`, when given,
    accumulates per-stage timings (hash_s/compress_s/io_s).
    """
    codec = codec or DEFAULT_CODEC
    _codec_pair(codec)                   # fail fast on an unknown codec
    ext = _codec_ext(codec)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    if store is None:
        store = chunkstore.open_store(None, default=ckpt_dir / "chunks")
    workers = DEFAULT_WORKERS if workers is None else workers
    root = getattr(store, "root", None)
    spec = getattr(store, "fetch_spec", "")
    leaves = _leaf_paths(state)
    manifest: Dict[str, Any] = {"version": 3, "codec": codec,
                                "leaves": {}, "meta": meta or {}}
    if root is not None:
        manifest["chunk_dir"] = os.path.relpath(root, ckpt_dir)
    if isinstance(spec, str) and spec.startswith("remote://"):
        # fetch-on-miss: a reader without the writer's disk can rebuild
        # chunk access from the manifest alone
        manifest["store"] = spec

    shards: List[tuple] = []             # (leaf_key, data, idx, dev)
    for key, leaf in leaves:
        arr = leaf
        if isinstance(arr, jax.Array):
            arr = HostArray(arr)
        entry: Dict[str, Any] = {}
        if isinstance(arr, HostArray):
            entry["shape"] = list(arr.shape)
            entry["dtype"] = arr.dtype
            # replicas were deduped at snapshot; dedup again here for
            # HostArrays built by older callers
            uniq: Dict[str, tuple] = {}
            for idx, data, dev in arr.shards:
                uniq.setdefault(json.dumps(idx), (idx, data, dev))
            for idx, data, dev in uniq.values():
                shards.append((key, data, idx, dev))
        else:
            data = np.asarray(arr)
            entry["shape"] = list(data.shape)
            entry["dtype"] = str(data.dtype)
            shards.append((key, data, [[0, d] for d in data.shape], -1))
        manifest["leaves"][key] = entry

    pool = ThreadPoolExecutor(max_workers=workers,
                              thread_name_prefix="ckpt-compress") \
        if workers > 1 else None
    jobs: List[Tuple[str, Any]] = []     # (leaf_key, future-or-result)
    try:
        if getattr(store, "wants_batched_has", False):
            # two-phase: hash everything (pool), ONE has_many round trip
            # for every candidate name this save could reference, then
            # compress/upload only the misses (pool again)
            def hashed(data):
                buf, digest, dh = _hash_shard(data)
                return buf, digest, _shuffle_itemsize(data.dtype), dh
            hs = [(key, (pool.submit(hashed, data) if pool
                         else hashed(data)), idx, dev)
                  for key, data, idx, dev in shards]
            hs = [(key, h if isinstance(h, tuple) else h.result(), idx, dev)
                  for key, h, idx, dev in hs]
            names: List[str] = []
            for _, (buf, digest, itemsize, _dh), _, _ in hs:
                names.extend(_hit_candidates(digest, ext, itemsize))
            presence = store.has_many(names)
            for key, (buf, digest, itemsize, dh), idx, dev in hs:
                if stats is not None:
                    stats["hash_s"] = stats.get("hash_s", 0.0) + dh
                args = (store, codec, ext, buf, digest, itemsize, idx, dev,
                        presence)
                jobs.append((key, pool.submit(_finish_shard, *args) if pool
                             else _finish_shard(*args)))
        else:
            for key, data, idx, dev in shards:
                args = (store, codec, ext, data, idx, dev)
                jobs.append((key, pool.submit(_write_shard, *args) if pool
                             else _write_shard(*args)))
        # collect in submission order so manifests are deterministic
        per_leaf: Dict[str, List[dict]] = {}
        for key, job in jobs:
            ent, (dh, dc, dio) = job if isinstance(job, tuple) \
                else job.result()
            per_leaf.setdefault(key, []).append(ent)
            if stats is not None:
                stats["hash_s"] = stats.get("hash_s", 0.0) + dh
                stats["compress_s"] = stats.get("compress_s", 0.0) + dc
                stats["io_s"] = stats.get("io_s", 0.0) + dio
        for key, leaf_shards in per_leaf.items():
            manifest["leaves"][key]["shards"] = leaf_shards
    finally:
        if pool is not None:
            pool.shutdown(wait=True)
    _atomic_write(ckpt_dir / "MANIFEST.json",
                  json.dumps(manifest, indent=1).encode())
    return manifest


def load_manifest(ckpt_dir: Path) -> dict:
    return json.loads((ckpt_dir / "MANIFEST.json").read_text())


def manifest_chunks(man: dict) -> List[str]:
    """Every chunk name a v3 manifest references (refcount-gc input).
    Empty for v1 manifests (their blobs live inside the step dir)."""
    if man.get("version", 1) < 3:
        return []
    return [s["chunk"] for e in man.get("leaves", {}).values()
            for s in e.get("shards", ())]


# --------------------------------------------------------------- chunk reads

def _shard_path(ckpt_dir: Path, man_or_chunk_dir, s: dict) -> Path:
    """Resolve a shard entry to its file: v3 entries name a chunk in the
    manifest's chunk_dir; v1 entries name a file inside the step dir."""
    if "chunk" in s:
        chunk_dir = (man_or_chunk_dir.get("chunk_dir", "chunks")
                     if isinstance(man_or_chunk_dir, dict)
                     else man_or_chunk_dir)
        return ckpt_dir / chunk_dir / s["chunk"]
    return ckpt_dir / s["file"]


def load_leaf(ckpt_dir: Path, entry: dict, verify: bool = True,
              codec: Optional[str] = None,
              chunk_dir: str = "chunks",
              reader: Optional[ChunkReader] = None,
              stats: Optional[dict] = None) -> np.ndarray:
    """Reassemble one logical array from its shard chunks.  `codec` must be
    the manifest's — pass ``manifest.get("codec", "zstd")`` (pre-codec
    manifests were always zstd; per-shard ``codec`` records override it
    for filtered chunks, and the chunk extension is authoritative).
    `reader` routes chunk reads (explicit store / local dir /
    fetch-on-miss); without one, reads are local files under `chunk_dir`.
    `stats` accumulates restore_io_s / restore_decompress_s."""
    if codec is None:
        raise ValueError(
            'pass the manifest codec: manifest.get("codec", "zstd")')
    shape = tuple(entry["shape"])
    # bfloat16 round-trips through jnp below; read raw bytes as uint16
    import jax.numpy as jnp
    jdt = jnp.dtype(entry["dtype"])
    out = np.zeros(shape, dtype=jdt)
    for s in entry["shards"]:
        t0 = time.perf_counter()
        if "chunk" in s and reader is not None:
            blob = reader.get(s["chunk"])
        else:
            blob = _shard_path(ckpt_dir, chunk_dir, s).read_bytes()
        t1 = time.perf_counter()
        if verify and "file" in s and zlib.crc32(blob) != s["crc32"]:
            raise IOError(f"{s['file']}: crc mismatch")
        if "chunk" in s:
            raw = decode_chunk(s["chunk"], blob, codec)
            if verify:
                # chunks are self-validating: the name IS the digest of
                # the unshuffled uncompressed content
                if content_digest(raw) != s["chunk"].split(".")[0]:
                    raise IOError(f"{s['chunk']}: content digest mismatch")
        else:
            raw = _codec_pair(codec)[1].decompress(blob)
        t2 = time.perf_counter()
        if stats is not None:
            stats["restore_io_s"] = stats.get("restore_io_s", 0.0) \
                + (t1 - t0)
            stats["restore_decompress_s"] = \
                stats.get("restore_decompress_s", 0.0) + (t2 - t1)
        idx = tuple(slice(a, b) for a, b in s["index"])
        window = out[idx].shape if idx else ()
        chunk = np.frombuffer(raw, dtype=jdt).reshape(window or shape)
        if idx:
            out[idx] = chunk
        else:
            out = chunk.reshape(shape).copy()
    return out


def iter_restored_leaves(ckpt_dir: Path, man: dict, keys: Sequence[str],
                         verify: bool = True,
                         store: Optional[ChunkStoreBackend] = None,
                         workers: Optional[int] = None,
                         stats: Optional[dict] = None
                         ) -> Iterator[Tuple[str, np.ndarray]]:
    """Yield ``(key, host array)`` in `keys` order, fetching and
    decompressing up to a bounded window of leaves AHEAD on a thread pool
    that mirrors the writer pool — the consumer's device_put of leaf k
    overlaps io+decompress of leaves k+1.. (the restore half of the
    DESIGN.md §9 pipeline).  ``workers<=1`` restores serially."""
    workers = DEFAULT_WORKERS if workers is None else workers
    codec = man.get("codec", "zstd")
    chunk_dir = man.get("chunk_dir", "chunks")
    reader = ChunkReader(ckpt_dir, man, store)

    # restore working set: one batched prefetch pins every cache-missing
    # chunk BEFORE the per-leaf gets — over a sharded store the set
    # arrives from N servers concurrently (one get_many per shard per
    # batch) instead of serializing on a single socket.  No-op for local
    # stores; a failed prefetch degrades to the per-chunk ladder.
    want = []
    for key in keys:
        for s in man["leaves"][key].get("shards", ()):
            if "chunk" in s:
                want.append(s["chunk"])
    if want:
        t0 = time.perf_counter()
        fetched = reader.prefetch(want)
        if stats is not None and fetched:
            stats["restore_prefetch_bytes"] = (
                stats.get("restore_prefetch_bytes", 0) + fetched)
            stats["restore_prefetch_s"] = (
                stats.get("restore_prefetch_s", 0.0)
                + (time.perf_counter() - t0))

    def one(key: str):
        # per-job stats dict: pool threads must not race on the shared one
        st: dict = {}
        arr = load_leaf(ckpt_dir, man["leaves"][key], verify, codec=codec,
                        chunk_dir=chunk_dir, reader=reader, stats=st)
        return arr, st

    def merge(st: dict) -> None:
        if stats is not None:
            for k, v in st.items():
                stats[k] = stats.get(k, 0.0) + v

    if workers <= 1 or len(keys) <= 1:
        for key in keys:
            arr, st = one(key)
            merge(st)
            yield key, arr
        return
    with ThreadPoolExecutor(max_workers=workers,
                            thread_name_prefix="ckpt-restore") as pool:
        window: deque = deque()
        ahead = max(2, workers * 2)          # bound host-memory in flight
        pending = iter(keys)
        for key in pending:
            window.append((key, pool.submit(one, key)))
            if len(window) >= ahead:
                k, fut = window.popleft()
                arr, st = fut.result()
                merge(st)
                yield k, arr
        while window:
            k, fut = window.popleft()
            arr, st = fut.result()
            merge(st)
            yield k, arr


def restore_tree(ckpt_dir: Path, template, verify: bool = True,
                 store: Optional[ChunkStoreBackend] = None,
                 workers: Optional[int] = None,
                 stats: Optional[dict] = None):
    """Restore into the structure of `template` (values ignored; tree shape
    and leaf order must match what was saved).  Leaves stream through the
    bounded restore pool; `store` routes chunk reads (fetch-on-miss for
    caching backends)."""
    man = load_manifest(ckpt_dir)
    keys = [k for k, _ in _leaf_paths(template)]
    missing = [k for k in keys if k not in man["leaves"]]
    if missing:
        raise KeyError(f"checkpoint missing leaves: {missing[:5]}")
    vals = [arr for _, arr in iter_restored_leaves(
        ckpt_dir, man, keys, verify, store=store, workers=workers,
        stats=stats)]
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, vals)


def validate(ckpt_dir: Path, deep: bool = False,
             store: Optional[ChunkStoreBackend] = None,
             raise_unreachable: bool = False) -> bool:
    """Checkpoint-dir validity.

    v3 fast path (the default): parse the manifest and check every
    referenced chunk's existence + recorded compressed length in ONE
    batched query (local stats, or one has_many round trip against a
    networked store) — no blob is read or decompressed, so
    ``latest_valid`` over a long history is manifest-only.  ``deep=True``
    additionally decompresses every chunk and re-derives its content
    digest (what restore enforces anyway).  v1 dirs always get the full
    crc32 read (their manifests carry no sizes).

    An UNREACHABLE chunk service normally reads as invalid (callers fall
    back to older checkpoints / fresh starts); pass
    ``raise_unreachable=True`` where invalid triggers DELETION (gc) so a
    transient outage can never be mistaken for corruption."""
    try:
        man = load_manifest(ckpt_dir)
        reader = ChunkReader(ckpt_dir, man, store)
        chunk_shards = []
        for entry in man["leaves"].values():
            for s in entry["shards"]:
                if "chunk" in s:
                    chunk_shards.append((entry, s))
                else:
                    path = _shard_path(ckpt_dir, man, s)
                    if zlib.crc32(path.read_bytes()) != s["crc32"]:
                        return False
        sizes = reader.sizes([s["chunk"] for _, s in chunk_shards])
        for entry, s in chunk_shards:
            if sizes.get(s["chunk"]) != s["clen"]:
                return False
        if deep:
            for entry, s in chunk_shards:
                try:
                    blob = reader.get(s["chunk"])
                    raw = decode_chunk(s["chunk"], blob,
                                       man.get("codec", "zstd"))
                except ConnectionError:
                    raise                # re-routed to the outer handler
                except Exception:        # any corruption-shaped failure
                    return False
                if content_digest(raw) != s["chunk"].split(".")[0]:
                    return False
        return True
    except (OSError, KeyError, json.JSONDecodeError, ValueError,
            RuntimeError) as e:
        if raise_unreachable and isinstance(e, ConnectionError):
            raise
        return False
