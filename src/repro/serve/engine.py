"""Batched serving engine: compiled prefill + decode with KV cache, greedy
sampling, slot-based batching, and — because the checkpoint boundary is a
pure pytree here too — CHECKPOINTABLE inference state (cache + positions +
generated tokens), restorable onto a different mesh.  That is the paper's
story applied to serving: an inference service can be drained, snapshotted
and moved across "implementations" (meshes/hosts) mid-generation.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingRules, sharding_ctx
from repro.models.layers import DEFAULT_POLICY, Policy
from repro.models.registry import get_api


@dataclass
class GenResult:
    tokens: np.ndarray              # (B, n_new)
    prefill_s: float
    decode_s: float
    tokens_per_s: float


class ServeEngine:
    def __init__(self, cfg: ArchConfig, params, mesh, rules: ShardingRules,
                 *, max_seq: int, policy: Policy = DEFAULT_POLICY):
        self.cfg = cfg
        self.params = params
        self.mesh = mesh
        self.rules = rules
        self.max_seq = max_seq
        self.policy = policy
        self.api = get_api(cfg)

        def prefill(params, tokens, extras):
            with sharding_ctx(mesh, rules):
                return self.api.prefill(cfg, params, tokens, extras, max_seq,
                                        )
        def decode(params, cache, token, pos):
            with sharding_ctx(mesh, rules):
                return self.api.decode(cfg, params, cache, token, pos)

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self.cache = None
        self.pos = None
        self.generated: List[np.ndarray] = []

    # ------------------------------------------------------------- generate
    def generate(self, prompts: np.ndarray, n_new: int,
                 extras: Optional[dict] = None) -> GenResult:
        """prompts (B, P) equal-length token batch; greedy decode n_new."""
        b, p = prompts.shape
        assert p + n_new <= self.max_seq, (p, n_new, self.max_seq)
        t0 = time.time()
        logits, cache = self._prefill(self.params, jnp.asarray(prompts),
                                      extras or {})
        # pad prefill cache (built at prompt length) up to max_seq buffers
        cache = self._pad_cache(cache, p)
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        jax.block_until_ready(tok)
        t_prefill = time.time() - t0

        t0 = time.time()
        pos = jnp.full((b,), p, jnp.int32)
        out = [np.asarray(tok)]
        for _ in range(n_new - 1):
            logits, cache = self._decode(self.params, cache, tok, pos)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos = pos + 1
            out.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode = time.time() - t0
        self.cache, self.pos = cache, pos + 1
        toks = np.concatenate(out, axis=1)
        self.generated.append(toks)
        return GenResult(tokens=toks, prefill_s=t_prefill, decode_s=t_decode,
                         tokens_per_s=b * max(n_new - 1, 1) / max(t_decode, 1e-9))

    def _pad_cache(self, cache, p: int):
        """Grow seq-dim buffers from prompt length to max_seq (zero fill).
        Target defs are built with batch=1; dims of size 1 in the target
        take the runtime batch, larger target dims are zero-padded."""
        from repro.models.params import is_pm
        target = self.api.cache_defs(self.cfg, 1, self.max_seq)

        def pad(x, tdef):
            tshape = [sx if st == 1 else max(sx, st)
                      for sx, st in zip(x.shape, tdef.shape)]
            pads = [(0, t - s) for s, t in zip(x.shape, tshape)]
            return jnp.pad(x, pads) if any(pp[1] for pp in pads) else x

        flat_t = jax.tree.leaves(target, is_leaf=is_pm)
        flat_x, treedef = jax.tree.flatten(cache)
        assert len(flat_t) == len(flat_x), (len(flat_t), len(flat_x))
        return jax.tree.unflatten(treedef,
                                  [pad(x, t) for x, t in zip(flat_x, flat_t)])

    # ----------------------------------------------------------- checkpoint
    def snapshot_service(self, mgr: CheckpointManager, step: int) -> None:
        """Drain (block) + snapshot serving state — paper FSM for serving."""
        payload = {"cache": self.cache,
                   "pos": self.pos,
                   "generated": np.concatenate(self.generated, axis=1)
                   if self.generated else np.zeros((0, 0), np.int32)}
        mgr.save(step, payload, meta={"kind": "serve", "arch": self.cfg.name})
        mgr.wait()
