"""Pallas TPU flash attention (forward): blocked online-softmax with
explicit VMEM BlockSpecs, GQA via index-map head folding, optional local
window (recurrentgemma), causal block skipping via @pl.when.

TPU adaptation notes (DESIGN.md §6): tile sizes are MXU-aligned (128); the
working set per grid step is q_tile(bq x hd) + k/v tiles (bk x hd) + the
f32 accumulator (bq x hd) + softmax stats — chosen to sit comfortably in
VMEM with double buffering.  A CUDA flash kernel parallelizes over warps
within the tile; on TPU the MXU consumes whole (128,128) tiles and the
sequential k-grid carries the online-softmax state in scratch.

Layout: q (BH, Sq, hd); k, v (BKV, Sk, hd); grid (BH, nq, nk), k-minor
(sequential) so scratch accumulators persist across the k sweep.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
               scale: float, block_q: int, block_k: int, causal: bool,
               window: int, n_k: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip fully-masked blocks (strictly above the diagonal / out of window)
    if causal:
        relevant = k_start <= q_start + block_q - 1
        if window:
            relevant = jnp.logical_and(
                relevant, k_start + block_k - 1 > q_start - window)
    else:
        relevant = jnp.bool_(True)

    @pl.when(relevant)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0].astype(jnp.float32)            # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 0)
            kpos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                      (block_q, block_k), 1)
            ok = kpos <= qpos
            if window:
                ok = jnp.logical_and(ok, kpos > qpos - window)
            s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]                          # (bq,)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _final():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        scale: float | None = None,
                        interpret: bool = False):
    """q (BH, Sq, hd); k, v (BKV, Sk, hd), BH = BKV * G.  Returns (BH, Sq, hd)."""
    bh, sq, hd = q.shape
    bkv, sk, _ = k.shape
    assert bh % bkv == 0, (bh, bkv)
    g = bh // bkv
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    n_q = sq // block_q
    n_k = sk // block_k
    scale = hd ** -0.5 if scale is None else scale

    kernel = functools.partial(
        _fa_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, n_k=n_k)

    return pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki, g=g: (b // g, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, qi, ki, g=g: (b // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
