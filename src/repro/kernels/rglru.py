"""Pallas TPU kernel for the RG-LRU linear recurrence
h_t = a_t * h_{t-1} + x_t  (gates precomputed by the caller).

TPU adaptation (DESIGN.md §6): a GPU implementation uses a warp-level
parallel scan; the TPU VPU instead prefers lane-parallel (over D) with a
short sequential walk over time INSIDE a VMEM-resident chunk, carrying h
across chunks in scratch — the sequential grid dimension is the time-chunk
axis, so the carry never leaves VMEM.  Grid: (B, n_d, n_chunks) with
chunks minor/sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(a_ref, x_ref, h0_ref, out_ref, hlast_ref, h_ref, *,
                  chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[0].astype(jnp.float32)          # (chunk, bd)
    x = x_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + x[t]
        out_ref[0, t, :] = h.astype(out_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == n_chunks - 1)
    def _final():
        hlast_ref[0] = h.astype(hlast_ref.dtype)


def rglru_scan(a, x, h0, *, chunk: int = 128, block_d: int = 512,
               interpret: bool = False):
    """a, x (B, S, D); h0 (B, D).  Returns (h_seq (B,S,D) fp32, h_last)."""
    b, s, d = a.shape
    chunk = min(chunk, s)
    block_d = min(block_d, d)
    assert s % chunk == 0 and d % block_d == 0, (s, chunk, d, block_d)
    n_chunks = s // chunk
    n_d = d // block_d

    kernel = functools.partial(_rglru_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(b, n_d, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, ci: (bi, di)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda bi, di, ci: (bi, ci, di)),
            pl.BlockSpec((1, block_d), lambda bi, di, ci: (bi, di)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, d), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d,), jnp.float32)],
        interpret=interpret,
    )(a, x, h0)
