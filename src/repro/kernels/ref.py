"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        scale: float | None = None):
    """q (BH, Sq, hd); k, v (BKV, Sk, hd) with BH = BKV * G.
    fp32 softmax, GQA via head-group folding."""
    bh, sq, hd = q.shape
    bkv, sk, _ = k.shape
    g = bh // bkv
    scale = hd ** -0.5 if scale is None else scale
    qf = q.reshape(bkv, g, sq, hd).astype(jnp.float32)
    s = jnp.einsum("bgqd,bkd->bgqk", qf, k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(sq)[:, None]
        kpos = jnp.arange(sk)[None, :]
        ok = kpos <= qpos
        if window:
            ok &= kpos > (qpos - window)
        s = jnp.where(ok[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqk,bkd->bgqd", p, v.astype(jnp.float32))
    return o.reshape(bh, sq, hd).astype(q.dtype)


def ref_rglru(a, x, h0):
    """Linear recurrence h_t = a_t * h_{t-1} + x_t.
    a, x (B, S, D) fp32; h0 (B, D).  Returns (h_seq (B,S,D), h_last)."""
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a = a.astype(jnp.float32)
    x = x.astype(jnp.float32)
    x0 = x.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))
    _, h = jax.lax.associative_scan(comb, (a, x0), axis=1)
    return h, h[:, -1]


def ref_quantize_int8(x, block: int = 256):
    """x (N,) fp32 (N % block == 0) -> (q int8 (N//block, block), scales)."""
    blocks = x.astype(jnp.float32).reshape(-1, block)
    scales = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127).astype(jnp.int8)
    return q, scales


def ref_dequantize_int8(q, scales):
    return (q.astype(jnp.float32) * scales[:, None]).reshape(-1)
