"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with interpret=True (the kernel
body runs in Python for correctness validation); on TPU they compile to
Mosaic.  ``flash_attention`` carries a custom_vjp whose backward is the
pure-jnp reference gradient (recompute-based) — the forward kernel is the
serving/prefill fast path; a fused backward kernel is listed as future
work in DESIGN.md §6."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import quantize as _q
from repro.kernels import rglru as _rg
from repro.kernels import ref as _ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------- attention

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, causal: bool = True, window: int = 0):
    """q (BH, Sq, hd); k, v (BKV, Sk, hd).  GQA folded by the caller."""
    return _fa.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   interpret=_interpret())


def _fa_fwd(q, k, v, causal, window):
    out = flash_attention(q, k, v, causal, window)
    return out, (q, k, v)


def _fa_bwd(causal, window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q, k, v: _ref.ref_flash_attention(q, k, v, causal=causal,
                                                 window=window), q, k, v)
    return vjp(g)


flash_attention.defvjp(_fa_fwd, _fa_bwd)


# ------------------------------------------------------------------- rg-lru

def rglru(a, x, h0):
    """h_t = a_t h_{t-1} + x_t over axis 1.  Returns (h_seq fp32, h_last)."""
    return _rg.rglru_scan(a, x, h0, interpret=_interpret())


# ----------------------------------------------------------------- quantize

def quantize_int8(x, block: int = 256):
    return _q.quantize_int8(x, block=block, interpret=_interpret())


def dequantize_int8(q, scales):
    return _q.dequantize_int8(q, scales, interpret=_interpret())
