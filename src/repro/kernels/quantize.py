"""Pallas TPU kernel: blockwise int8 quantize / dequantize (gradient
compression for the DCN axis + checkpoint compression).

Lane layout: one grid step handles ``rows`` scale-blocks of ``block``
elements each — (rows, block) sits in VMEM as an 8x128-aligned tile; the
per-block max|.| reduction runs on the VPU, and the int8 output quarters
HBM/DCN traffic."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                    # (rows, block)
    scale = jnp.maximum(jnp.max(jnp.abs(x), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale[:, None]), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale.astype(jnp.float32)


def _dequant_kernel(q_ref, s_ref, x_ref):
    x_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...][:, None]).astype(x_ref.dtype)


def quantize_int8(x, *, block: int = 256, rows: int = 64,
                  interpret: bool = False):
    """x (N,) with N % block == 0 -> (q (N//block, block) int8, scales)."""
    n = x.shape[0]
    assert n % block == 0, (n, block)
    nb = n // block
    rows = min(rows, nb)
    while nb % rows:
        rows -= 1
    xb = x.reshape(nb, block)
    return pl.pallas_call(
        _quant_kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                   pl.BlockSpec((rows,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb, block), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(xb)


def dequantize_int8(q, scales, *, rows: int = 64, interpret: bool = False):
    """(q (nb, block) int8, scales (nb,)) -> x (nb*block,) fp32."""
    nb, block = q.shape
    rows = min(rows, nb)
    while nb % rows:
        rows -= 1
    out = pl.pallas_call(
        _dequant_kernel,
        grid=(nb // rows,),
        in_specs=[pl.BlockSpec((rows, block), lambda i: (i, 0)),
                  pl.BlockSpec((rows,), lambda i: (i,))],
        out_specs=pl.BlockSpec((rows, block), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block), jnp.float32),
        interpret=interpret,
    )(q, scales)
    return out.reshape(-1)
