"""Elastic / cross-topology restart: train on a 2-device mesh, checkpoint,
then RESUME THE SAME CHECKPOINT on 4 devices and on 1 device — the paper's
"checkpoint on MPICH, restart on OpenMPI" at the tensor level (DESIGN.md
§2).  Each world runs in a subprocess with its own XLA device count.

  PYTHONPATH=src python examples/elastic_restart.py
"""
import json
import os
import subprocess
import sys
import tempfile
from pathlib import Path

SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={ndev}"
import json, sys
import jax
from repro.configs import ARCHS, reduce_for_smoke
from repro.distributed.sharding import make_variant
from repro.launch.mesh import make_local_mesh
from repro.train.loop import train

cfg = reduce_for_smoke(ARCHS["smollm-135m"])
mesh = make_local_mesh(model={model})
res = train(cfg, mesh, make_variant("baseline"), n_steps={steps},
            global_batch=8, seq_len=32, log_every=1, seed=3,
            ckpt_root=r"{root}", ckpt_every={every})
print(json.dumps({{"devices": len(jax.devices()),
                   "mesh": dict(mesh.shape),
                   "resumed_from": res.resumed_from,
                   "losses": res.losses[-3:]}}))
"""


def run_world(ndev: int, model: int, steps: int, root: str, every: int = 5):
    code = SNIPPET.format(ndev=ndev, model=model, steps=steps, root=root,
                          every=every)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=900,
                       env={**os.environ, "PYTHONPATH": "src"})
    if r.returncode != 0:
        print(r.stderr[-2000:])
        raise SystemExit(1)
    return json.loads(r.stdout.strip().splitlines()[-1])


def main() -> None:
    with tempfile.TemporaryDirectory() as d:
        root = str(Path(d) / "ck")
        print("[1/3] train 10 steps on a (1,2) mesh (2 devices), ckpt@10")
        a = run_world(2, 2, 10, root, every=5)
        print("      ", a)
        print("[2/3] resume the SAME checkpoint on (2,2) mesh (4 devices)")
        b = run_world(4, 2, 20, root, every=5)
        print("      ", b)
        assert b["resumed_from"] == 10, b
        print("[3/3] resume again on a SINGLE device")
        c = run_world(1, 1, 22, root, every=50)
        print("      ", c)
        assert c["resumed_from"] == 20, c
    print("RESULT: one checkpoint, three topologies (2 -> 4 -> 1 devices) — "
          "cross-implementation restart works")


if __name__ == "__main__":
    main()
