"""Quickstart: the paper in 60 seconds.

Four MPI ranks train a data-parallel model whose gradient allreduce rides
MPI_Send/MPI_Recv through per-rank PROXIES.  Mid-run we checkpoint
asynchronously (network drained, in-flight gradient chunks cached), kill
the job, and restart it ON A DIFFERENT MPI IMPLEMENTATION (tcp sockets
instead of shared-memory queues).  Final parameters are bitwise identical
to an uninterrupted run.

  PYTHONPATH=src python examples/quickstart.py
"""
import tempfile
from pathlib import Path

import numpy as np

from repro.core import MPIJob
from repro.distributed.proxy_grad import make_dp_app

N_RANKS, STEPS, CKPT_AT = 4, 16, 9


def main() -> None:
    init_fn, step_fn = make_dp_app(lr=0.05)

    print(f"[1/3] uninterrupted reference run ({STEPS} steps, shm)")
    ref_job = MPIJob(N_RANKS, step_fn, init_fn, transport="shm")
    ref = ref_job.run(STEPS)
    ref_job.stop()
    print(f"      final loss {ref[0]['loss']:.5f}")

    with tempfile.TemporaryDirectory() as d:
        ck = Path(d) / "ckpt"
        print(f"[2/3] same run, checkpoint+exit at step {CKPT_AT} (shm)")
        job = MPIJob(N_RANKS, step_fn, init_fn, transport="shm")
        job.checkpoint_at(CKPT_AT, ck, resume=False)
        job.run(STEPS)
        job.stop()
        stats = job.coord.stats
        print(f"      drained {stats['drained_messages']} in-flight messages "
              f"in {stats['drain_wall_s']*1e3:.1f} ms")

        print("[3/3] restart from the checkpoint on TCP sockets")
        job2 = MPIJob.restart(ck, step_fn, init_fn, transport="tcp")
        out = job2.run(STEPS)
        job2.stop()

    same = all(np.array_equal(out[r]["params"][k], ref[r]["params"][k])
               for r in range(N_RANKS) for k in ref[r]["params"])
    print(f"      final loss {out[0]['loss']:.5f}")
    print(f"RESULT: cross-implementation restart bitwise identical: {same}")
    assert same


if __name__ == "__main__":
    main()
