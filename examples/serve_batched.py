"""Batched serving demo: compiled prefill + KV-cache decode, with the
serving state checkpointed mid-generation (a service can be drained,
snapshotted and moved — the paper's claim applied to inference).

  PYTHONPATH=src python examples/serve_batched.py --arch yi-9b --batch 4
"""
import argparse
import tempfile
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.distributed.sharding import make_variant
from repro.launch.mesh import make_local_mesh
from repro.models.params import init_params
from repro.models.registry import get_api
from repro.serve.engine import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b", choices=sorted(ARCHS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_arch(args.arch))
    api = get_api(cfg)
    max_seq = args.prompt_len + args.new_tokens + 8
    params = init_params(api.param_defs(cfg, max_seq), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, make_local_mesh(),
                      make_variant("baseline"), max_seq=max_seq)

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    extras = {}
    if cfg.family == "audio":
        extras["frames"] = np.ones(
            (args.batch, cfg.encoder.n_frames, cfg.d_model), np.float32) * .1
    if cfg.family == "vlm":
        extras["vision_embeds"] = np.ones(
            (args.batch, cfg.n_vision_tokens, cfg.d_model), np.float32) * .1

    res = eng.generate(prompts, args.new_tokens, extras=extras)
    print(f"arch={cfg.name} batch={args.batch}: prefill {res.prefill_s*1e3:.0f}ms, "
          f"decode {res.decode_s*1e3:.0f}ms "
          f"({res.tokens_per_s:.0f} tok/s), out shape {res.tokens.shape}")
    print("first sequence:", res.tokens[0][:12], "...")

    with tempfile.TemporaryDirectory() as d:
        eng.snapshot_service(CheckpointManager(Path(d) / "svc"), step=1)
        n_files = len(list((Path(d) / "svc" / "step_0000000001").iterdir()))
        print(f"serving state checkpointed mid-generation ({n_files} files) "
              f"— cache+positions are a pure pytree, restorable on any mesh")


if __name__ == "__main__":
    main()
