"""End-to-end training driver: train an assigned architecture with the
fault-tolerant loop (async checkpoints, auto-resume, deterministic data).

  # ~100M-param SmolLM-135M, short demo schedule:
  PYTHONPATH=src python examples/train_driver.py --arch smollm-135m \
      --steps 300 --batch 8 --seq 128 --preset full

  # fast CPU demo (reduced config):
  PYTHONPATH=src python examples/train_driver.py --steps 40 --preset tiny

  # crash/recovery demo: first invocation dies at step 25, second resumes
  PYTHONPATH=src python examples/train_driver.py --steps 40 --preset tiny \
      --ckpt-dir /tmp/ck --kill-at 25
  PYTHONPATH=src python examples/train_driver.py --steps 40 --preset tiny \
      --ckpt-dir /tmp/ck
"""
import argparse
import time

from repro.configs import ARCHS, get_arch, reduce_for_smoke
from repro.distributed.sharding import make_variant
from repro.launch.mesh import make_local_mesh
from repro.train.loop import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=sorted(ARCHS))
    ap.add_argument("--preset", default="tiny", choices=("tiny", "full"))
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--kill-at", type=int, default=None)
    ap.add_argument("--variant", default="baseline")
    args = ap.parse_args()

    cfg = get_arch(args.arch)
    if args.preset == "tiny":
        cfg = reduce_for_smoke(cfg)
    print(f"arch={cfg.name} ({cfg.n_params()/1e6:.1f}M params) "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    mesh = make_local_mesh()
    rules = make_variant(args.variant)
    t0 = time.time()
    try:
        res = train(cfg, mesh, rules, n_steps=args.steps,
                    global_batch=args.batch, seq_len=args.seq,
                    base_lr=args.lr, ckpt_root=args.ckpt_dir,
                    ckpt_every=args.ckpt_every, log_every=5,
                    fail_at_step=args.kill_at, seed=0)
    except RuntimeError as e:
        print(f"CRASHED (as requested): {e} — rerun to auto-resume")
        raise SystemExit(0)
    tok_s = args.steps * args.batch * args.seq / res.wall_s
    print(f"losses: {['%.4f' % l for l in res.losses[:3]]} ... "
          f"{['%.4f' % l for l in res.losses[-3:]]}")
    if res.resumed_from is not None:
        print(f"auto-resumed from checkpoint at step {res.resumed_from}")
    print(f"done in {time.time()-t0:.1f}s ({tok_s:.0f} tok/s); "
          f"ckpt stats: {res.ckpt_stats}")


if __name__ == "__main__":
    main()
